"""PolyBench-derived SCoP definitions (paper §IV-B/C, Fig. 2/3/4).

Each ``make_<kernel>()`` builds the SCoP with concrete dataset sizes
(PolyBench MEDIUM-ish, tuned so C-backend runs take O(0.1–1 s) on this
box). Scalar accumulators of the original C kernels are modeled as
1-element arrays (the polyhedral representation is identical).

Kernels whose optimization needs negative schedule coefficients
(nussinov, deriche, adi) fall back to the original schedule — exactly
the behaviour the paper reports for PolyTOPS and Pluto; nussinov's
body is additionally non-affine (max), so it is represented here by its
affine core only for fallback demonstration.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

from .scop import Scop

SIZE = {
    "gemm": 420, "mm2": 260, "mm3": 220, "atax": 1900, "bicg": 1900,
    "mvt": 2000, "gesummv": 1300, "gemver": 2000, "symm": 300,
    "syrk": 320, "syr2k": 260, "trmm": 340, "trisolv": 2000,
    "cholesky": 340, "lu": 300, "gramschmidt": 240,
    "covariance": 300, "correlation": 300, "doitgen": (128, 128, 64),
    "jacobi1d": (500, 16000), "jacobi2d": (100, 450),
    "heat3d": (60, 90), "fdtd2d": (120, 400), "seidel2d": (60, 400),
    "durbin": 1200,
}

Registry = Dict[str, Callable[[], Scop]]
REGISTRY: Registry = {}


def register(fn):
    REGISTRY[fn.__name__.replace("make_", "")] = fn
    return fn


@register
def make_gemm(n: Optional[int] = None) -> Scop:
    n = n or SIZE["gemm"]
    k = Scop("gemm", params={"N": n, "M": n, "K": n})
    with k.loop("i", 0, "N"):
        with k.loop("j", 0, "M"):
            k.stmt("C[i,j] = C[i,j] * beta")
            with k.loop("kk", 0, "K"):
                k.stmt("C[i,j] = C[i,j] + alpha * A[i,kk] * B[kk,j]")
    return k


@register
def make_mm2(n: Optional[int] = None) -> Scop:
    n = n or SIZE["mm2"]
    k = Scop("mm2", params={"N": n})
    with k.loop("i", 0, "N"):
        with k.loop("j", 0, "N"):
            k.stmt("tmp[i,j] = 0.0 * zero")
            with k.loop("kk", 0, "N"):
                k.stmt("tmp[i,j] = tmp[i,j] + alpha * A[i,kk] * B[kk,j]")
    with k.loop("i2", 0, "N"):
        with k.loop("j2", 0, "N"):
            k.stmt("D[i2,j2] = D[i2,j2] * beta")
            with k.loop("k2", 0, "N"):
                k.stmt("D[i2,j2] = D[i2,j2] + tmp[i2,k2] * C[k2,j2]")
    return k


@register
def make_mm3(n: Optional[int] = None) -> Scop:
    n = n or SIZE["mm3"]
    k = Scop("mm3", params={"N": n})
    with k.loop("i", 0, "N"):
        with k.loop("j", 0, "N"):
            k.stmt("E[i,j] = 0.0 * zero")
            with k.loop("kk", 0, "N"):
                k.stmt("E[i,j] = E[i,j] + A[i,kk] * B[kk,j]")
    with k.loop("i2", 0, "N"):
        with k.loop("j2", 0, "N"):
            k.stmt("F[i2,j2] = 0.0 * zero")
            with k.loop("k2", 0, "N"):
                k.stmt("F[i2,j2] = F[i2,j2] + C[i2,k2] * D[k2,j2]")
    with k.loop("i3", 0, "N"):
        with k.loop("j3", 0, "N"):
            k.stmt("G[i3,j3] = 0.0 * zero")
            with k.loop("k3", 0, "N"):
                k.stmt("G[i3,j3] = G[i3,j3] + E[i3,k3] * F[k3,j3]")
    return k


@register
def make_atax(n: Optional[int] = None) -> Scop:
    n = n or SIZE["atax"]
    k = Scop("atax", params={"N": n, "M": n})
    with k.loop("i0", 0, "N"):
        k.stmt("y[i0] = 0.0 * zero")
    with k.loop("i", 0, "M"):
        k.stmt("tmp[i] = 0.0 * zero")
        with k.loop("j", 0, "N"):
            k.stmt("tmp[i] = tmp[i] + A[i,j] * x[j]")
        with k.loop("j2", 0, "N"):
            k.stmt("y[j2] = y[j2] + A[i,j2] * tmp[i]")
    return k


@register
def make_bicg(n: Optional[int] = None) -> Scop:
    n = n or SIZE["bicg"]
    k = Scop("bicg", params={"N": n, "M": n})
    with k.loop("i0", 0, "M"):
        k.stmt("s[i0] = 0.0 * zero")
    with k.loop("i", 0, "N"):
        k.stmt("q[i] = 0.0 * zero")
        with k.loop("j", 0, "M"):
            k.stmt("s[j] = s[j] + r[i] * A[i,j]")
            k.stmt("q[i] = q[i] + A[i,j] * p[j]")
    return k


@register
def make_mvt(n: Optional[int] = None) -> Scop:
    n = n or SIZE["mvt"]
    k = Scop("mvt", params={"N": n})
    with k.loop("i", 0, "N"):
        with k.loop("j", 0, "N"):
            k.stmt("x1[i] = x1[i] + A[i,j] * y1[j]")
    with k.loop("i2", 0, "N"):
        with k.loop("j2", 0, "N"):
            k.stmt("x2[i2] = x2[i2] + A[j2,i2] * y2[j2]")
    return k


@register
def make_gesummv(n: Optional[int] = None) -> Scop:
    n = n or SIZE["gesummv"]
    k = Scop("gesummv", params={"N": n})
    with k.loop("i", 0, "N"):
        k.stmt("tmp[i] = 0.0 * zero")
        k.stmt("y[i] = 0.0 * zero")
        with k.loop("j", 0, "N"):
            k.stmt("tmp[i] = A[i,j] * x[j] + tmp[i]")
            k.stmt("y[i] = B[i,j] * x[j] + y[i]")
        k.stmt("y[i] = alpha * tmp[i] + beta * y[i]", name="S4")
    return k


@register
def make_gemver(n: Optional[int] = None) -> Scop:
    n = n or SIZE["gemver"]
    k = Scop("gemver", params={"N": n})
    with k.loop("i", 0, "N"):
        with k.loop("j", 0, "N"):
            k.stmt("A[i,j] = A[i,j] + u1[i] * v1[j] + u2[i] * v2[j]")
    with k.loop("i2", 0, "N"):
        with k.loop("j2", 0, "N"):
            k.stmt("x[i2] = x[i2] + beta * A[j2,i2] * y[j2]")
    with k.loop("i3", 0, "N"):
        k.stmt("x[i3] = x[i3] + z[i3]")
    with k.loop("i4", 0, "N"):
        with k.loop("j4", 0, "N"):
            k.stmt("w[i4] = w[i4] + alpha * A[i4,j4] * x[j4]")
    return k


@register
def make_symm(n: Optional[int] = None) -> Scop:
    n = n or SIZE["symm"]
    k = Scop("symm", params={"N": n, "M": n})
    # C := alpha*A*B + beta*C with A symmetric (lower stored)
    with k.loop("i", 0, "M"):
        with k.loop("j", 0, "N"):
            with k.loop("kk", 0, "i"):
                k.stmt("C[kk,j] = C[kk,j] + alpha * B[i,j] * A[i,kk]")
                k.stmt("temp2[i,j] = temp2[i,j] + B[kk,j] * A[i,kk]")
            k.stmt("C[i,j] = beta * C[i,j] + alpha * B[i,j] * A[i,i] + alpha * temp2[i,j]")
    return k


@register
def make_syrk(n: Optional[int] = None) -> Scop:
    n = n or SIZE["syrk"]
    k = Scop("syrk", params={"N": n, "M": n})
    with k.loop("i", 0, "N"):
        with k.loop("j", 0, "i+1"):
            k.stmt("C[i,j] = C[i,j] * beta")
        with k.loop("kk", 0, "M"):
            with k.loop("j2", 0, "i+1"):
                k.stmt("C[i,j2] = C[i,j2] + alpha * A[i,kk] * A[j2,kk]")
    return k


@register
def make_syr2k(n: Optional[int] = None) -> Scop:
    n = n or SIZE["syr2k"]
    k = Scop("syr2k", params={"N": n, "M": n})
    with k.loop("i", 0, "N"):
        with k.loop("j", 0, "i+1"):
            k.stmt("C[i,j] = C[i,j] * beta")
        with k.loop("kk", 0, "M"):
            with k.loop("j2", 0, "i+1"):
                k.stmt("C[i,j2] = C[i,j2] + A[j2,kk]*alpha*B[i,kk] + B[j2,kk]*alpha*A[i,kk]")
    return k


@register
def make_trmm(n: Optional[int] = None) -> Scop:
    n = n or SIZE["trmm"]
    k = Scop("trmm", params={"N": n, "M": n})
    with k.loop("i", 0, "M"):
        with k.loop("j", 0, "N"):
            with k.loop("kk", "i+1", "M"):
                k.stmt("B[i,j] = B[i,j] + A[kk,i] * B[kk,j]")
            k.stmt("B[i,j] = alpha * B[i,j]")
    return k


@register
def make_trisolv(n: Optional[int] = None) -> Scop:
    n = n or SIZE["trisolv"]
    k = Scop("trisolv", params={"N": n})
    with k.loop("i", 0, "N"):
        k.stmt("x[i] = b[i]")
        with k.loop("j", 0, "i"):
            k.stmt("x[i] = x[i] - L[i,j] * x[j]")
        k.stmt("x[i] = x[i] / L[i,i]")
    k.c_init["L"] = (
        "((i0 == i1) ? (2.0 * N) : (0.5 * ((double)((i0*7 + i1*13) % 251)) / 251.0))"
    )
    return k


@register
def make_cholesky(n: Optional[int] = None) -> Scop:
    n = n or SIZE["cholesky"]
    k = Scop("cholesky", params={"N": n})
    with k.loop("i", 0, "N"):
        with k.loop("j", 0, "i"):
            with k.loop("kk", 0, "j"):
                k.stmt("A[i,j] = A[i,j] - A[i,kk] * A[j,kk]")
            k.stmt("A[i,j] = A[i,j] / A[j,j]")
        with k.loop("k2", 0, "i"):
            k.stmt("A[i,i] = A[i,i] - A[i,k2] * A[i,k2]")
        k.stmt("A[i,i] = sqrt(A[i,i])")
    # positive-definite input (diagonally dominant), as in PolyBench init
    k.c_init["A"] = (
        "((i0 == i1) ? (2.0 * N) : 0.0)"
        " + ((double)((i0*7 + i1*13 + 3) % 251)) / 251.0"
    )
    k.np_init["A"] = _spd_init
    return k


def _spd_init(shape, rng):
    """Symmetric diagonally-dominant (hence positive-definite) matrix —
    the numpy oracle's counterpart of the cholesky ``c_init`` above;
    with the default noise init the factorization hits ``sqrt`` of
    negative intermediates and fills A with NaNs."""
    import numpy as np

    n = shape[0]
    a = rng.standard_normal(shape) * 0.1 + 1.0
    a = (a + a.T) / 2.0
    a[np.diag_indices(n)] = 2.0 * n
    return a


@register
def make_lu(n: Optional[int] = None) -> Scop:
    n = n or SIZE["lu"]
    k = Scop("lu", params={"N": n})
    with k.loop("i", 0, "N"):
        with k.loop("j", 0, "i"):
            with k.loop("kk", 0, "j"):
                k.stmt("A[i,j] = A[i,j] - A[i,kk] * A[kk,j]")
            k.stmt("A[i,j] = A[i,j] / A[j,j]")
        with k.loop("j2", "i", "N"):
            with k.loop("k2", 0, "i"):
                k.stmt("A[i,j2] = A[i,j2] - A[i,k2] * A[k2,j2]")
    k.c_init["A"] = (
        "((i0 == i1) ? (2.0 * N) : 0.0)"
        " + ((double)((i0*7 + i1*13 + 3) % 251)) / 251.0"
    )
    return k


@register
def make_gramschmidt(n: Optional[int] = None) -> Scop:
    n = n or SIZE["gramschmidt"]
    k = Scop("gramschmidt", params={"N": n, "M": n})
    with k.loop("kk", 0, "N"):
        k.stmt("nrm[kk] = 0.0 * zero")
        with k.loop("i", 0, "M"):
            k.stmt("nrm[kk] = nrm[kk] + A[i,kk] * A[i,kk]")
        k.stmt("R[kk,kk] = sqrt(nrm[kk])")
        with k.loop("i2", 0, "M"):
            k.stmt("Q[i2,kk] = A[i2,kk] / R[kk,kk]")
        with k.loop("j", "kk+1", "N"):
            k.stmt("R[kk,j] = 0.0 * zero")
            with k.loop("i3", 0, "M"):
                k.stmt("R[kk,j] = R[kk,j] + Q[i3,kk] * A[i3,j]")
            with k.loop("i4", 0, "M"):
                k.stmt("A[i4,j] = A[i4,j] - Q[i4,kk] * R[kk,j]")
    return k


@register
def make_covariance(n: Optional[int] = None) -> Scop:
    n = n or SIZE["covariance"]
    k = Scop("covariance", params={"N": n, "M": n})
    with k.loop("j", 0, "M"):
        k.stmt("mean[j] = 0.0 * zero")
        with k.loop("i", 0, "N"):
            k.stmt("mean[j] = mean[j] + data[i,j]")
        k.stmt("mean[j] = mean[j] / fn")
    with k.loop("i2", 0, "N"):
        with k.loop("j2", 0, "M"):
            k.stmt("data[i2,j2] = data[i2,j2] - mean[j2]")
    with k.loop("i3", 0, "M"):
        with k.loop("j3", "i3", "M"):
            k.stmt("cov[i3,j3] = 0.0 * zero")
            with k.loop("k3", 0, "N"):
                k.stmt("cov[i3,j3] = cov[i3,j3] + data[k3,i3] * data[k3,j3]")
            k.stmt("cov[i3,j3] = cov[i3,j3] / (fn - 1.0)")
            k.stmt("cov[j3,i3] = cov[i3,j3]")
    return k


@register
def make_correlation(n: Optional[int] = None) -> Scop:
    n = n or SIZE["correlation"]
    k = Scop("correlation", params={"N": n, "M": n})
    with k.loop("j", 0, "M"):
        k.stmt("mean[j] = 0.0 * zero")
        with k.loop("i", 0, "N"):
            k.stmt("mean[j] = mean[j] + data[i,j]")
        k.stmt("mean[j] = mean[j] / fn")
    with k.loop("j1", 0, "M"):
        k.stmt("stddev[j1] = 0.0 * zero")
        with k.loop("i1", 0, "N"):
            k.stmt("stddev[j1] = stddev[j1] + (data[i1,j1]-mean[j1]) * (data[i1,j1]-mean[j1])")
        k.stmt("stddev[j1] = sqrt(stddev[j1] / fn) + eps")
    with k.loop("i2", 0, "N"):
        with k.loop("j2", 0, "M"):
            k.stmt("data[i2,j2] = (data[i2,j2] - mean[j2]) / (sqrt(fn) * stddev[j2])")
    with k.loop("i3", 0, "M"):
        k.stmt("corr[i3,i3] = 1.0 * one")
        with k.loop("j3", "i3+1", "M"):
            k.stmt("corr[i3,j3] = 0.0 * zero")
            with k.loop("k3", 0, "N"):
                k.stmt("corr[i3,j3] = corr[i3,j3] + data[k3,i3] * data[k3,j3]")
            k.stmt("corr[j3,i3] = corr[i3,j3]")
    return k


@register
def make_doitgen(sz: Optional[Tuple[int, int, int]] = None) -> Scop:
    r, q, p = sz or SIZE["doitgen"]
    k = Scop("doitgen", params={"R": r, "Q": q, "P": p})
    with k.loop("r", 0, "R"):
        with k.loop("q", 0, "Q"):
            with k.loop("p", 0, "P"):
                k.stmt("sum[r,q,p] = 0.0 * zero")
                with k.loop("s", 0, "P"):
                    k.stmt("sum[r,q,p] = sum[r,q,p] + A[r,q,s] * C4[s,p]")
            with k.loop("p2", 0, "P"):
                k.stmt("A[r,q,p2] = sum[r,q,p2]")
    return k


@register
def make_jacobi1d(sz: Optional[Tuple[int, int]] = None) -> Scop:
    t, n = sz or SIZE["jacobi1d"]
    k = Scop("jacobi1d", params={"T": t, "N": n})
    with k.loop("t", 0, "T"):
        with k.loop("i", 1, "N-1"):
            k.stmt("B[i] = 0.33333 * (A[i-1] + A[i] + A[i+1])")
        with k.loop("i2", 1, "N-1"):
            k.stmt("A[i2] = 0.33333 * (B[i2-1] + B[i2] + B[i2+1])")
    return k


@register
def make_jacobi2d(sz: Optional[Tuple[int, int]] = None) -> Scop:
    t, n = sz or SIZE["jacobi2d"]
    k = Scop("jacobi2d", params={"T": t, "N": n})
    with k.loop("t", 0, "T"):
        with k.loop("i", 1, "N-1"):
            with k.loop("j", 1, "N-1"):
                k.stmt("B[i,j] = 0.2 * (A[i,j] + A[i,j-1] + A[i,j+1] + A[i+1,j] + A[i-1,j])")
        with k.loop("i2", 1, "N-1"):
            with k.loop("j2", 1, "N-1"):
                k.stmt("A[i2,j2] = 0.2 * (B[i2,j2] + B[i2,j2-1] + B[i2,j2+1] + B[i2+1,j2] + B[i2-1,j2])")
    return k


@register
def make_heat3d(sz: Optional[Tuple[int, int]] = None) -> Scop:
    t, n = sz or SIZE["heat3d"]
    k = Scop("heat3d", params={"T": t, "N": n})
    with k.loop("t", 0, "T"):
        with k.loop("i", 1, "N-1"):
            with k.loop("j", 1, "N-1"):
                with k.loop("m", 1, "N-1"):
                    k.stmt(
                        "B[i,j,m] = 0.125*(A[i+1,j,m]-2.0*A[i,j,m]+A[i-1,j,m])"
                        " + 0.125*(A[i,j+1,m]-2.0*A[i,j,m]+A[i,j-1,m])"
                        " + 0.125*(A[i,j,m+1]-2.0*A[i,j,m]+A[i,j,m-1]) + A[i,j,m]"
                    )
        with k.loop("i2", 1, "N-1"):
            with k.loop("j2", 1, "N-1"):
                with k.loop("m2", 1, "N-1"):
                    k.stmt(
                        "A[i2,j2,m2] = 0.125*(B[i2+1,j2,m2]-2.0*B[i2,j2,m2]+B[i2-1,j2,m2])"
                        " + 0.125*(B[i2,j2+1,m2]-2.0*B[i2,j2,m2]+B[i2,j2-1,m2])"
                        " + 0.125*(B[i2,j2,m2+1]-2.0*B[i2,j2,m2]+B[i2,j2,m2-1]) + B[i2,j2,m2]"
                    )
    return k


@register
def make_fdtd2d(sz: Optional[Tuple[int, int]] = None) -> Scop:
    t, n = sz or SIZE["fdtd2d"]
    k = Scop("fdtd2d", params={"T": t, "N": n, "M": n})
    with k.loop("t", 0, "T"):
        with k.loop("j", 0, "M"):
            k.stmt("ey[0,j] = fict[t]")
        with k.loop("i", 1, "N"):
            with k.loop("j2", 0, "M"):
                k.stmt("ey[i,j2] = ey[i,j2] - 0.5*(hz[i,j2] - hz[i-1,j2])")
        with k.loop("i2", 0, "N"):
            with k.loop("j3", 1, "M"):
                k.stmt("ex[i2,j3] = ex[i2,j3] - 0.5*(hz[i2,j3] - hz[i2,j3-1])")
        with k.loop("i3", 0, "N-1"):
            with k.loop("j4", 0, "M-1"):
                k.stmt("hz[i3,j4] = hz[i3,j4] - 0.7*(ex[i3,j4+1] - ex[i3,j4] + ey[i3+1,j4] - ey[i3,j4])")
    return k


@register
def make_seidel2d(sz: Optional[Tuple[int, int]] = None) -> Scop:
    t, n = sz or SIZE["seidel2d"]
    k = Scop("seidel2d", params={"T": t, "N": n})
    with k.loop("t", 0, "T"):
        with k.loop("i", 1, "N-1"):
            with k.loop("j", 1, "N-1"):
                k.stmt(
                    "A[i,j] = (A[i-1,j-1] + A[i-1,j] + A[i-1,j+1] + A[i,j-1]"
                    " + A[i,j] + A[i,j+1] + A[i+1,j-1] + A[i+1,j] + A[i+1,j+1]) / 9.0"
                )
    return k


@register
def make_durbin(n: Optional[int] = None) -> Scop:
    n = n or SIZE["durbin"]
    # scalar accumulators modeled as 1-element arrays (z: workspace per iter)
    k = Scop("durbin", params={"N": n})
    with k.loop("kk", 1, "N"):
        k.stmt("sum[kk] = 0.0 * zero")
        with k.loop("i", 0, "kk"):
            k.stmt("sum[kk] = sum[kk] + r[kk-i-1] * y[i,kk-1]")
        k.stmt("alpha[kk] = -(r[kk] + sum[kk]) / beta[kk-1]")
        k.stmt("beta[kk] = beta[kk-1] * (1.0 - alpha[kk] * alpha[kk])")
        with k.loop("i2", 0, "kk"):
            k.stmt("y[i2,kk] = y[i2,kk-1] + alpha[kk] * y[kk-i2-1,kk-1]")
        k.stmt("y[kk,kk] = alpha[kk]")
    # keep |alpha| < 1 so the recursion stays bounded
    k.c_init["r"] = "0.01 * ((double)((i0*7 + 3) % 251)) / 251.0"
    k.c_init["y"] = "0.01 * ((double)((i0*7 + i1*13 + 3) % 251)) / 251.0"
    k.c_init["beta"] = "1.0"
    k.c_init["sum"] = "0.0"
    k.c_init["alpha"] = "0.0"
    return k


def all_kernels() -> Registry:
    return dict(REGISTRY)
