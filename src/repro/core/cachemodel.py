"""Cache-model tile sizing (paper §III-E kernel-specific configuration).

PolyTOPS deliberately takes *no* tile-size decision in the core
scheduler — sizes are provided externally.  This module is that external
provider for the CPU measurement path (and, with a VMEM budget, for the
Pallas/TPU kernel plans in :mod:`repro.core.akg`): instead of a fixed
``tile=32`` it derives per-band, per-dimension tile sizes from the
SCoP's access functions so that one tile's working set fits a target
cache level.

Model
-----
For a tilable band (schedule dims ``[start, start+length)``, fully
permutable by construction) and a statement scanned by it, every array
access is summarized by the *stride matrix* ``c[j][d]`` = how much array
subscript ``j`` moves per unit step of band dim ``d`` (computed through
the schedule's iterator substitution, so skewed bands are handled).
Accesses to the same array whose stride rows agree are one *access
group* (``C[i,j]`` read + write, the three points of a stencil, ...);
within a group only the constant offsets differ and their spread widens
the footprint.  One tile of sizes ``T`` then touches, per group,

    elems(T) = prod_j (spread_j + 1 + sum_d |c[j][d]| * (T_d - 1))

and the tile working set is ``elem_bytes * sum_groups elems(T)``.

Sizes are chosen by deterministic greedy doubling: starting from
``min_tile`` in every dim, repeatedly double the dimension with the
highest temporal-reuse weight (number of access groups *not* moved by
that dim — those groups are re-touched ``T_d`` times, so growing ``T_d``
amortizes the most traffic), tie-broken toward balanced tiles, while the
working set stays under budget.  The result is a power-of-two tile
vector that fits the cache — per band and per statement group, exactly
the "cache-model-driven selector" the kernel-specific configurations
plug in.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from fractions import Fraction
from typing import Dict, List, Optional, Sequence, Tuple

from .schedtree import (ScanStmt, iterator_substitution, scan_from_schedule,
                        yvar as _yvar)
from .scheduler import Schedule


@dataclass(frozen=True)
class CacheSpec:
    """Target memory hierarchy for tile sizing."""
    l1_bytes: int = 32 * 1024
    l2_bytes: int = 512 * 1024
    line_bytes: int = 64
    elem_bytes: int = 8       # double

    def budget(self, level: str) -> int:
        if level == "l1":
            return self.l1_bytes
        if level in ("l2", "auto"):
            return self.l2_bytes
        raise ValueError(f"unknown cache level {level!r}")


def default_spec() -> CacheSpec:
    """CacheSpec with env overrides (POLYTOPS_L1_BYTES / POLYTOPS_L2_BYTES)."""
    return CacheSpec(
        l1_bytes=int(os.environ.get("POLYTOPS_L1_BYTES", 32 * 1024)),
        l2_bytes=int(os.environ.get("POLYTOPS_L2_BYTES", 512 * 1024)),
    )


# ---------------------------------------------------------------------------
# access groups: stride signature of every access wrt the band dims
# ---------------------------------------------------------------------------


@dataclass
class AccessGroup:
    array: str
    strides: Tuple[Tuple[Fraction, ...], ...]   # [array_dim][band_dim]
    spread: List[Fraction]                      # constant-offset spread per dim

    def tile_elems(self, sizes: Sequence[int]) -> int:
        total = 1
        for j, row in enumerate(self.strides):
            extent = Fraction(1) + self.spread[j]
            for d, c in enumerate(row):
                if c:
                    extent += abs(c) * (sizes[d] - 1)
            total *= max(1, int(extent))
        return total

    def reused_by(self, d: int) -> bool:
        """True when band dim d does not move this access (temporal reuse:
        the whole group footprint is re-touched T_d times)."""
        return all(row[d] == 0 for row in self.strides)


def band_access_groups(scan: Sequence[ScanStmt], start: int,
                       length: int) -> List[AccessGroup]:
    """Access groups of all statements scanned by the band, deduplicated
    across statements by (array, stride signature, offset pattern)."""
    band = [_yvar(start + k) for k in range(length)]
    # key -> (strides, per-array-dim [min_const, max_const])
    acc_info: Dict[tuple, Tuple[tuple, List[List[Fraction]]]] = {}
    for ss in scan:
        if ss.n_dims() <= start:
            continue
        try:
            subst = iterator_substitution(ss)
        except ValueError:
            continue                     # non-invertible: skip statement
        for acc in ss.stmt.accesses:
            strides = []
            base_consts = []
            base_rest = []
            for e in acc.subscripts:
                row = []
                for y in band:
                    c = Fraction(0)
                    for it, v in e.items():
                        if it in subst:
                            c += v * subst[it].get(y, Fraction(0))
                    row.append(c)
                strides.append(tuple(row))
                # substituted expr minus the band terms: constant part and
                # the non-constant remainder (params / outer dims)
                const = Fraction(0)
                rest: Dict[object, Fraction] = {}
                for it, v in e.items():
                    if it == 1:
                        const += v
                    elif it in subst:
                        for k2, v2 in subst[it].items():
                            if k2 == 1:
                                const += v * v2
                            elif k2 not in band:
                                rest[k2] = rest.get(k2, Fraction(0)) + v * v2
                    else:
                        rest[it] = rest.get(it, Fraction(0)) + v
                base_consts.append(const)
                base_rest.append(tuple(sorted(
                    (str(k), v) for k, v in rest.items() if v)))
            key = (acc.array, tuple(strides), tuple(base_rest))
            entry = acc_info.get(key)
            if entry is None:
                acc_info[key] = (tuple(strides),
                                 [[c, c] for c in base_consts])
            else:
                for j, c in enumerate(base_consts):
                    entry[1][j][0] = min(entry[1][j][0], c)
                    entry[1][j][1] = max(entry[1][j][1], c)
    return [
        AccessGroup(key[0], strides, [mx - mn for mn, mx in mm])
        for key, (strides, mm) in acc_info.items()
    ]


def working_set_bytes(groups: Sequence[AccessGroup], sizes: Sequence[int],
                      elem_bytes: int = 8) -> int:
    return elem_bytes * sum(g.tile_elems(sizes) for g in groups)


def stmt_iter_ranges(scop, stmt) -> Dict[str, Optional[Tuple[Fraction, Fraction]]]:
    """Rational (min, max) of each statement iterator over its domain
    with the SCoP's concrete parameter values, or None when the LP finds
    no bound — the shared extent primitive behind the autotuner's trip
    estimate and the AKG/Pallas VMEM tile fitter."""
    from .polyhedron import maximum, minimum

    cons = list(stmt.domain) + scop.param_rows()
    out: Dict[str, Optional[Tuple[Fraction, Fraction]]] = {}
    for it in stmt.iters:
        hi = maximum(cons, {it: Fraction(1)})
        lo = minimum(cons, {it: Fraction(1)})
        out[it] = None if hi is None or lo is None else (lo, hi)
    return out


def stmt_access_groups(stmt, iters: Sequence[str]) -> List[AccessGroup]:
    """Access groups over the statement's own iterators (identity
    schedule) — the working-set primitive for consumers that tile by
    iterator name rather than by schedule band (the AKG/Pallas VMEM
    fitter)."""
    acc_info: Dict[tuple, Tuple[tuple, List[List[Fraction]]]] = {}
    for acc in stmt.accesses:
        strides = []
        base_consts = []
        base_rest = []
        for e in acc.subscripts:
            strides.append(tuple(e.get(it, Fraction(0)) for it in iters))
            base_consts.append(e.get(1, Fraction(0)))
            # non-iterator remainder (parameters): accesses offset by a
            # parametric distance (A[i] vs A[i+N]) are separate groups,
            # not one group with zero spread
            base_rest.append(tuple(sorted(
                (str(k), v) for k, v in e.items()
                if k != 1 and k not in iters and v)))
        key = (acc.array, tuple(strides), tuple(base_rest))
        entry = acc_info.get(key)
        if entry is None:
            acc_info[key] = (tuple(strides), [[c, c] for c in base_consts])
        else:
            for j, c in enumerate(base_consts):
                entry[1][j][0] = min(entry[1][j][0], c)
                entry[1][j][1] = max(entry[1][j][1], c)
    return [
        AccessGroup(key[0], strides, [mx - mn for mn, mx in mm])
        for key, (strides, mm) in acc_info.items()
    ]


# ---------------------------------------------------------------------------
# extents + selection
# ---------------------------------------------------------------------------


def _band_extents(sched: Schedule, scan: Sequence[ScanStmt], start: int,
                  length: int, cap: int = 1 << 20) -> List[int]:
    """Estimated trip count of each band dim (max over statements), with
    the SCoP's concrete parameter values."""
    from .polyhedron import maximum, minimum

    scop = sched.scop
    ctx = scop.param_rows()
    extents = [1] * length
    for ss in scan:
        cons = list(ss.stmt.domain) + ctx
        for k in range(length):
            if start + k >= ss.n_dims():
                continue
            phi = ss.dims[start + k].phi
            if not any(it in ss.stmt.iters for it in phi):
                continue
            hi = maximum(cons, phi)
            lo = minimum(cons, phi)
            if hi is None or lo is None:
                extents[k] = cap
                continue
            extents[k] = max(extents[k], min(cap, int(hi - lo) + 1))
    return extents


def select_tile_sizes(sched: Schedule, start: int, length: int,
                      budget_bytes: Optional[int] = None,
                      spec: Optional[CacheSpec] = None,
                      scan: Optional[Sequence[ScanStmt]] = None,
                      min_tile: int = 4, max_tile: int = 512) -> List[int]:
    """Tile sizes for one band by greedy doubling under the budget."""
    spec = spec or default_spec()
    if budget_bytes is None:
        budget_bytes = spec.l2_bytes
    scan = scan if scan is not None else scan_from_schedule(sched)
    groups = band_access_groups(scan, start, length)
    extents = _band_extents(sched, scan, start, length)
    if not groups:
        return [32] * length     # no access info: legacy default
    reuse = [sum(1 for g in groups if g.reused_by(d)) for d in range(length)]
    sizes = [max(1, min(min_tile, extents[d])) for d in range(length)]
    while True:
        best = None
        for d in range(length):
            nd = sizes[d] * 2
            if nd > max_tile or nd > extents[d]:
                continue
            trial = list(sizes)
            trial[d] = nd
            if working_set_bytes(groups, trial, spec.elem_bytes) > budget_bytes:
                continue
            # highest reuse first; then the smallest current size (keep
            # tiles balanced); then lowest dim index — fully deterministic
            key = (reuse[d], -sizes[d], -d)
            if best is None or key > best[0]:
                best = (key, d)
        if best is None:
            break
        sizes[best[1]] *= 2
    return sizes


# ---------------------------------------------------------------------------
# shared per-schedule memo: the autotuner's analytic cost model and the
# learned ranker's feature extraction score the same candidates over the
# same handful of schedules — these helpers give both one set of memo
# keys (keyed on id(schedule)) so every intermediate is computed once.
# ---------------------------------------------------------------------------


def shared_scan(sched: Schedule, memo: dict):
    key = ("scan", id(sched))
    if key not in memo:
        memo[key] = scan_from_schedule(sched)
    return memo[key]


def shared_bands(sched: Schedule, memo: dict):
    from .postproc import find_tilable_bands

    key = ("bands", id(sched))
    if key not in memo:
        memo[key] = find_tilable_bands(sched)
    return memo[key]


def shared_groups(sched: Schedule, memo: dict, start: int, length: int):
    key = ("groups", id(sched), start)
    if key not in memo:
        memo[key] = band_access_groups(shared_scan(sched, memo), start, length)
    return memo[key]


def shared_tile_sizes(sched: Schedule, memo: dict, tile,
                      spec: Optional[CacheSpec] = None) -> Dict[int, List[int]]:
    """Per-band tile sizes for a candidate tile source (int or cache
    level), memoized: ``{band_start: [sizes]}``."""
    spec = spec or default_spec()
    bands = shared_bands(sched, memo)
    key = ("sizes", id(sched), str(tile))
    if key not in memo:
        memo[key] = (
            {b.start: [int(tile)] * b.length for b in bands}
            if isinstance(tile, int)
            else auto_tile_sizes(sched, level=str(tile), spec=spec,
                                 bands=bands)
        )
    return memo[key]


def auto_tile_sizes(sched: Schedule, level: str = "l2",
                    spec: Optional[CacheSpec] = None,
                    bands=None) -> Dict[int, List[int]]:
    """Per-band tile sizes for every tilable band of ``sched``:
    ``{band_start: [sizes]}`` — the shape ``postproc.tile_schedule``
    consumes."""
    from .postproc import find_tilable_bands

    spec = spec or default_spec()
    budget = spec.budget(level)
    scan = scan_from_schedule(sched)
    if bands is None:
        bands = find_tilable_bands(sched)
    return {
        b.start: select_tile_sizes(sched, b.start, b.length, budget,
                                   spec, scan=scan)
        for b in bands
    }
