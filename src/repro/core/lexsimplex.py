"""Exact rational lexicographic simplex — the default lexmin backend.

The scheduler computes schedules as lexicographic minima of small ILPs
(paper §III-A1).  Solving them with a floating-point MIP solver (HiGHS)
made the *optimum value* reliable but the *optimal vertex* a coin flip:
equally-legal alternate optima were picked depending on row ordering,
warm starts and tolerances, which left ~4/56 kernel×strategy combos
where the seed and incremental pipelines disagreed (ROADMAP residual).
This module removes the float solver from the loop:

* **Fraction-free integer tableau** — the simplex dictionary is kept as
  an integer matrix with one denominator per row (`basic_i = (M[i,0] +
  Σ_j M[i,j+1]·nonbasic_j) / den[i]`).  Pivots are two vectorized
  numpy int64 passes; rows are gcd-normalized after every pivot and the
  whole tableau is promoted to exact Python ints (object dtype) the
  moment an int64 overflow is possible, so arithmetic is always exact.
* **Feasibility** via the single-artificial-variable trick (Chvátal):
  one column, one forced pivot to the most-violated row, then minimize
  the artificial with the ordinary primal loop.
* **Primal simplex** with Dantzig pricing and a deterministic switch to
  Bland's rule after a degenerate streak — finite termination, and every
  choice (entering, leaving, ties) is a pure function of the tableau.
* **Integrality** by bounded depth-first branch & bound on the
  (box-bounded) scheduler variables, exact Fraction bound pruning.
* **Lexmin** runs append-only on one tableau: each stage optimizes from
  the previous stage's basis and appends a single `obj ≤ val` fixing
  row (sound for integer points: `obj ≥ val` is implied by optimality).
  Box-bounded integer suffix stages are collapsed into one exactly
  weighted objective — with exact arithmetic there is no big-M
  tolerance cap, so the scheduler's whole canonical tail is one solve.
* **Canonicalization** — after the caller's objectives, the requested
  ``canon`` variables are minimized lexicographically as final stages
  (folded into the same weighted objective).  This makes the returned
  point *mathematically unique* on the canon variables: any two
  algorithms solving the same problem — the seed pipeline, the
  incremental pipeline, a re-run — return bit-identical schedule
  coefficients.  Determinism is a property of the answer, not of the
  pivot path.

The tableau consumes problems through :class:`repro.core.ilp.ILPProblem`
(which compiles its rational rows into reusable integer arrays, see
``LexCompiled``); it deliberately does not import that module.
"""
from __future__ import annotations

from fractions import Fraction
from math import gcd
from typing import Dict, List, Optional, Tuple

import numpy as np

from .linalg_q import rationals_to_int_row

# recorded in schedule-cache keys: bump when pivoting/canonicalization
# semantics change in a way that can alter returned optima
SOLVER_TAG = "lexsimplex-1"

# promote the tableau to exact Python ints past this magnitude
_I64_GUARD = 1 << 61
# degenerate pivots before switching from Dantzig to Bland pricing
_BLAND_AFTER = 40
# branch & bound safety valve (never reached by scheduler problems)
_BB_NODE_LIMIT = 50_000


class Unbounded(Exception):
    """Objective unbounded below over the feasible region."""


class PivotLimit(Exception):
    """Safety valve tripped (cycling or a runaway branch & bound)."""


# ---------------------------------------------------------------------------
# compiled integer image of an ILPProblem (exact twin of CompiledProblem)
# ---------------------------------------------------------------------------

class LexCompiled:
    """Append-only integer-scaled image of an ILPProblem's vars/cons.

    Each model variable maps to one tableau column (shifted so its lower
    bound is 0) or to a split pair ``x = x⁺ − x⁻`` when free.  Each
    constraint row becomes one (``>=0``) or two (``==0``) integer rows
    ``const + Σ coef·col ≥ 0``; upper bounds become explicit rows.
    ``truncate`` rewinds to an earlier var/row count — the same
    contract :class:`repro.core.ilp.CompiledProblem` has, driven by
    ``ILPProblem.push``/``pop``.
    """

    def __init__(self):
        self.n_vars = 0                    # model vars consumed
        self.n_rows = 0                    # model cons consumed
        self.cols: List[Tuple] = []   # per var: ('one',ent,lb)|('two',entp,entn,ub)
        self.col_names: List[str] = []
        self._name_idx: Dict[str, int] = {}
        self.ent_var: List[Tuple[str, int]] = []  # entity -> (name, +1|-1)
        self.integer: List[bool] = []      # per entity
        self.ub: List[Optional[Fraction]] = []  # per entity (shifted)
        self.rows: List[Tuple[Tuple[int, ...], Tuple[int, ...], int]] = []
        # each row: (entity idx tuple, int coef tuple, int const)
        self._row_marks: List[int] = []    # rows emitted per source con

    @property
    def n_entities(self) -> int:
        return len(self.ent_var)

    def sync(self, prob) -> None:
        names = list(prob.vars)
        for name in names[self.n_vars:]:
            v = prob.vars[name]
            if v.lb is None:
                entp, entn = len(self.ent_var), len(self.ent_var) + 1
                # the model ub rides on the spec: a bound on xp−xn is a
                # general row over both entities, not a per-entity box
                self.cols.append(("two", entp, entn, v.ub))
                self.ent_var.extend([(name, 1), (name, -1)])
                self.integer.extend([v.integer, v.integer])
                self.ub.extend([None, None])
            else:
                if v.integer and v.lb.denominator != 1:
                    raise ValueError(f"integer var {name} has fractional lb")
                ent = len(self.ent_var)
                self.cols.append(("one", ent, v.lb))
                self.ent_var.append((name, 1))
                self.integer.append(v.integer)
                ub = None if v.ub is None else v.ub - v.lb
                if v.integer and ub is not None and ub.denominator != 1:
                    raise ValueError(f"integer var {name} has fractional ub")
                self.ub.append(ub)
            self._name_idx[name] = len(self.col_names)
            self.col_names.append(name)
        self.n_vars = len(names)
        for expr, kind in prob.cons[self.n_rows:]:
            emitted = self._emit(prob, expr, kind)
            self._row_marks.append(emitted)
        self.n_rows = len(prob.cons)

    def _affine_to_row(self, prob, expr) -> Tuple[List[int], List[int], int]:
        idxs: List[int] = []
        coefs: List[Fraction] = []
        const = expr.get(1, Fraction(0))
        order = self._name_idx
        cols = self.cols
        for k, c in expr.items():
            if k == 1 or not c:
                continue
            spec = cols[order[k]]
            if spec[0] == "one":
                if spec[2]:
                    const = const + c * spec[2]   # x = lb + x'
                idxs.append(spec[1])
                coefs.append(c)
            else:
                idxs.extend([spec[1], spec[2]])
                coefs.extend([c, -c])
        ints, den = rationals_to_int_row(coefs + [const])
        return idxs, ints[:-1], ints[-1]

    def _emit(self, prob, expr, kind) -> int:
        idxs, ints, const = self._affine_to_row(prob, expr)
        self.rows.append((tuple(idxs), tuple(ints), const))
        if kind == "==0":
            self.rows.append((tuple(idxs), tuple(-c for c in ints), -const))
            return 2
        return 1

    def truncate(self, n_vars: int, n_rows: int) -> None:
        while self.n_rows > n_rows:
            emitted = self._row_marks.pop()
            del self.rows[len(self.rows) - emitted:]
            self.n_rows -= 1
        while self.n_vars > n_vars:
            spec = self.cols.pop()
            del self._name_idx[self.col_names.pop()]
            drop = 1 if spec[0] == "one" else 2
            del self.ent_var[len(self.ent_var) - drop:]
            del self.integer[len(self.integer) - drop:]
            del self.ub[len(self.ub) - drop:]
            self.n_vars -= 1

    # -- tableau construction ---------------------------------------------
    def tableau(self) -> "Tableau":
        n = self.n_entities
        rows = list(self.rows)
        for ent, ub in enumerate(self.ub):
            if ub is not None:
                # ub = p/q:  q·(ub − x) = p − q·x ≥ 0
                rows.append(((ent,), (-ub.denominator,), ub.numerator))
        for spec in self.cols:
            if spec[0] == "two" and spec[3] is not None:
                ub = spec[3]   # ub − (x⁺ − x⁻) ≥ 0, scaled integer
                rows.append(((spec[1], spec[2]),
                             (-ub.denominator, ub.denominator),
                             ub.numerator))
        m = len(rows)
        M = np.zeros((m, n + 1), dtype=np.int64)
        for i, (idxs, ints, const) in enumerate(rows):
            M[i, 0] = const
            for j, c in zip(idxs, ints):
                M[i, j + 1] += c
        den = np.ones(m, dtype=np.int64)
        return Tableau(self, M, den)


# ---------------------------------------------------------------------------
# the tableau
# ---------------------------------------------------------------------------

class Tableau:
    """Fraction-free simplex dictionary.

    ``M`` has one column per *nonbasic* entity plus the constant column
    0; one row per *basic* entity.  Structural entities are
    ``0..n_struct-1``; slack entities get ids from ``n_struct`` up; the
    phase-1 artificial is entity ``-1`` (never present outside
    ``make_feasible``).  ``row_ent[i]``/``col_ent[j]`` name the basic /
    nonbasic entity of each row / column.  All entities are ≥ 0.
    """

    def __init__(self, comp: LexCompiled, M, den):
        self.comp = comp
        self.M = M
        self.den = den
        n = comp.n_entities
        self.row_ent = list(range(n, n + M.shape[0]))
        self.col_ent = list(range(n))
        self.next_slack = n + M.shape[0]
        self.obj: List[Tuple[np.ndarray, int]] = []
        # shared (not copied) across B&B child tableaus, so the count
        # covers the whole solve tree — both for reporting and for the
        # pivot-limit safety valve
        self._stats = {"pivots": 0}

    @property
    def pivots(self) -> int:
        return self._stats["pivots"]

    def copy(self) -> "Tableau":
        t = object.__new__(Tableau)
        t.comp = self.comp
        t.M = self.M.copy()
        t.den = self.den.copy()
        t.row_ent = list(self.row_ent)
        t.col_ent = list(self.col_ent)
        t.next_slack = self.next_slack
        t.obj = [(z.copy(), zd) for z, zd in self.obj]
        t._stats = self._stats
        return t

    # -- exact arithmetic helpers -----------------------------------------
    def _promote(self) -> None:
        if self.M.dtype == object:
            return
        self.M = self.M.astype(object)
        self.den = self.den.astype(object)

    def _reduce_rows(self, rows=None) -> None:
        M, den = self.M, self.den
        if M.dtype == object:
            it = range(M.shape[0]) if rows is None else rows
            for i in it:
                g = int(den[i])
                for v in M[i]:
                    g = gcd(g, abs(int(v)))
                    if g == 1:
                        break
                if g > 1:
                    M[i] //= g
                    den[i] //= g
            return
        g = np.gcd.reduce(np.abs(M), axis=1)
        g = np.gcd(g, np.abs(self.den))
        mask = g > 1
        if mask.any():
            M[mask] //= g[mask, None]
            den[mask] //= g[mask]

    def _pivot(self, r: int, jc: int) -> None:
        self._stats["pivots"] += 1
        M, den = self.M, self.den
        a = int(M[r, jc + 1])
        dr = int(den[r])
        assert a != 0
        if M.dtype != object:
            mx = int(np.abs(M).max(initial=0))
            mxd = int(np.abs(den).max(initial=0))
            col_mx = int(np.abs(M[:, jc + 1]).max(initial=0))
            row_mx = int(np.abs(M[r]).max(initial=0))
            if (abs(a) * mx + col_mx * row_mx > _I64_GUARD
                    or col_mx * dr > _I64_GUARD
                    or abs(a) * mxd > _I64_GUARD):
                self._promote()
                M, den = self.M, self.den
        Mr = M[r].copy()
        col = M[:, jc + 1].copy()
        M *= a
        M -= np.outer(col, Mr)
        M[:, jc + 1] = col * dr
        newr = -Mr
        newr[jc + 1] = dr
        M[r] = newr
        den *= a
        den[r] = a
        if a < 0:            # every denominator carries a's sign: flip
            M *= -1
            den *= -1
        # objective rows transform like ordinary rows
        for oi, (z, zd) in enumerate(self.obj):
            if z.dtype != object and (
                    abs(a) * int(np.abs(z).max(initial=0))
                    + abs(int(z[jc + 1])) * int(np.abs(Mr).max(initial=0))
                    > _I64_GUARD or abs(a) * abs(zd) > _I64_GUARD):
                z = z.astype(object)
            B = z[jc + 1]
            z2 = z * a - B * Mr.astype(z.dtype, copy=False)
            z2[jc + 1] = B * dr
            zd2 = zd * a
            if zd2 < 0:
                z2, zd2 = -z2, -zd2
            g = int(abs(zd2))
            for v in z2:
                g = gcd(g, abs(int(v)))
                if g == 1:
                    break
            if g > 1:
                z2 //= g
                zd2 //= g
            self.obj[oi] = (z2, int(zd2))
        self.row_ent[r], self.col_ent[jc] = self.col_ent[jc], self.row_ent[r]
        self._reduce_rows()

    # -- queries -----------------------------------------------------------
    def value_of(self, ent: int) -> Fraction:
        try:
            i = self.row_ent.index(ent)
        except ValueError:
            return Fraction(0)
        return Fraction(int(self.M[i, 0]), int(self.den[i]))

    def entity_values(self) -> Dict[int, Fraction]:
        out = {ent: Fraction(0) for ent in range(self.comp.n_entities)}
        for i, ent in enumerate(self.row_ent):
            if ent < self.comp.n_entities:
                out[ent] = Fraction(int(self.M[i, 0]), int(self.den[i]))
        return out

    def solution(self) -> Dict[str, Fraction]:
        vals = self.entity_values()
        out: Dict[str, Fraction] = {}
        for name, spec in zip(self.comp.col_names, self.comp.cols):
            if spec[0] == "one":
                _, ent, lb = spec
                out[name] = lb + vals[ent]
            else:
                out[name] = vals[spec[1]] - vals[spec[2]]
        return out

    # -- row / objective construction --------------------------------------
    def _express(self, coefs: Dict[int, Fraction], const: Fraction):
        """An affine form over entities, rewritten over the current
        nonbasic columns: returns (int vector len ncols+1, den)."""
        ncols = self.M.shape[1] - 1
        vec = [const] + [Fraction(0)] * ncols
        col_of = {e: j for j, e in enumerate(self.col_ent)}
        row_of = {e: i for i, e in enumerate(self.row_ent)}
        for ent, c in coefs.items():
            if not c:
                continue
            j = col_of.get(ent)
            if j is not None:
                vec[j + 1] += c
                continue
            i = row_of[ent]
            f = c / int(self.den[i])
            row = self.M[i]
            for l in range(ncols + 1):
                v = int(row[l])
                if v:
                    vec[l] += f * v
        return rationals_to_int_row(vec)

    def append_row(self, coefs: Dict[int, Fraction], const: Fraction) -> int:
        ints, den = self._express(coefs, const)
        if den > _I64_GUARD or any(abs(v) > _I64_GUARD for v in ints):
            self._promote()
        arr = np.asarray(ints, dtype=object)
        if self.M.dtype != object:
            arr = arr.astype(np.int64)
        self.M = np.vstack([self.M, arr[None, :]])
        self.den = np.append(self.den, np.asarray([den], dtype=self.den.dtype))
        ent = self.next_slack
        self.next_slack += 1
        self.row_ent.append(ent)
        return ent

    def push_objective(self, coefs: Dict[int, Fraction],
                       const: Fraction = Fraction(0)) -> None:
        ints, den = self._express(coefs, const)
        arr = np.asarray(ints, dtype=object)
        try:
            arr = arr.astype(np.int64)
        except OverflowError:
            pass
        self.obj.append((arr, den))

    def pop_objective(self) -> None:
        self.obj.pop()

    def objective_value(self) -> Fraction:
        z, zd = self.obj[-1]
        return Fraction(int(z[0]), int(zd))

    # -- simplex loops ------------------------------------------------------
    def _leave_for(self, jc: int) -> Optional[int]:
        """Primal ratio test for entering column jc: the leaving row
        keeping all basic values ≥ 0, exact, ties by smallest entity.

        A float pass pre-filters the candidates (generous tolerance so
        the true minimum can never be excluded); the winner among the
        survivors is chosen by exact cross-multiplication, so the result
        is identical to a fully exact scan."""
        col = self.M[:, jc + 1]
        cand = np.flatnonzero(col < 0)
        if cand.size == 0:
            return None
        if cand.size > 8 and self.M.dtype != object:
            num = self.M[cand, 0].astype(np.float64)
            denom = (-col[cand]).astype(np.float64)
            rat = num / denom
            m = rat.min()
            cand = cand[rat <= m + abs(m) * 1e-6 + 1e-9]
        best = None
        bn = bd = 0
        for i in cand:
            i = int(i)
            n, d = int(self.M[i, 0]), -int(col[i])
            if best is None or n * bd < bn * d or (
                    n * bd == bn * d and self.row_ent[i] < self.row_ent[best]):
                best, bn, bd = i, n, d
        return best

    def optimize(self) -> Fraction:
        """Minimize the top objective from the current (feasible) basis."""
        degen = 0
        while True:
            z, zd = self.obj[-1]
            neg = np.flatnonzero(z[1:] < 0)
            if neg.size == 0:
                return Fraction(int(z[0]), int(zd))
            if degen > _BLAND_AFTER:
                jc = min((int(j) for j in neg),
                         key=lambda j: self.col_ent[j])
            else:
                vals = z[1:][neg]
                jc = min((int(j) for j in neg[vals == vals.min()]),
                         key=lambda j: self.col_ent[j])
            r = self._leave_for(jc)
            if r is None:
                raise Unbounded()
            degen = degen + 1 if int(self.M[r, 0]) == 0 else 0
            self._pivot(r, jc)
            if self.pivots > 200_000:
                raise PivotLimit("primal simplex pivot limit")

    def make_feasible(self) -> bool:
        """Restore ``basic ≥ 0`` via the single artificial variable."""
        M = self.M
        if M.shape[0] == 0 or bool((M[:, 0] >= 0).all()):
            return True
        # append the artificial column: every basic row gains +x0
        x0col = M.shape[1] - 1
        self.M = np.hstack([M, self.den[:, None].copy()])
        self.col_ent.append(-1)
        self.obj = [(np.append(z, np.zeros(1, dtype=z.dtype)), zd)
                    for z, zd in self.obj]
        # forced pivot: most violated row (exact min of const/den)
        cand = np.flatnonzero(self.M[:, 0] < 0)
        best = None
        bn = bd = 0
        for i in cand:
            i = int(i)
            n, d = int(self.M[i, 0]), int(self.den[i])
            if best is None or n * bd < bn * d or (
                    n * bd == bn * d and self.row_ent[i] < self.row_ent[best]):
                best, bn, bd = i, n, d
        self._pivot(best, x0col)
        self.push_objective({-1: Fraction(1)})
        try:
            val = self.optimize()
        finally:
            self.pop_objective()
        feasible = val == 0
        # drive x0 out of the basis if it parked there at value 0
        if feasible and -1 in self.row_ent:
            r = self.row_ent.index(-1)
            row = self.M[r]
            piv = None
            for j in range(self.M.shape[1] - 1):
                if int(row[j + 1]) != 0 and self.col_ent[j] != -1:
                    if piv is None or self.col_ent[j] < self.col_ent[piv]:
                        piv = j
            if piv is None:
                self.M = np.delete(self.M, r, axis=0)
                self.den = np.delete(self.den, r)
                self.row_ent.pop(r)
            else:
                self._pivot(r, piv)
        if -1 in self.col_ent:
            j = self.col_ent.index(-1)
            self.M = np.delete(self.M, j + 1, axis=1)
            self.col_ent.pop(j)
            self.obj = [(np.delete(z, j + 1), zd) for z, zd in self.obj]
        return feasible


# ---------------------------------------------------------------------------
# branch & bound
# ---------------------------------------------------------------------------

def _first_fractional(tab: Tableau) -> Optional[Tuple[int, Fraction]]:
    """Smallest-id structural integer entity with a fractional value
    (nonbasic entities sit at 0 and are always integral)."""
    comp = tab.comp
    best = None
    for i, ent in enumerate(tab.row_ent):
        if (ent < comp.n_entities and comp.integer[ent]
                and (best is None or ent < best[0])):
            v = Fraction(int(tab.M[i, 0]), int(tab.den[i]))
            if v.denominator != 1:
                best = (ent, v)
    return best


def ilp_min(tab: Tableau, coefs: Dict[int, Fraction],
            const: Fraction = Fraction(0)):
    """Exact integer minimum of an affine objective over the tableau's
    region.  Returns ``(value, entity_values)`` or ``None`` (infeasible).
    The root tableau is left at its *LP-relaxation* optimum (callers
    append a fixing row and re-repair).  Deterministic: DFS, ≤-branch
    first, smallest fractional entity, exact bound pruning."""
    if not tab.make_feasible():
        return None
    tab.push_objective(coefs, const)
    try:
        root_val = tab.optimize()
    except Unbounded:
        tab.pop_objective()
        raise
    frac = _first_fractional(tab)
    if frac is None:
        vals = tab.entity_values()
        tab.pop_objective()
        return root_val, vals
    best: Optional[Tuple[Fraction, Dict[int, Fraction]]] = None
    stack = [(tab.copy(), root_val)]
    tab.pop_objective()
    nodes = 0
    while stack:
        t, bound = stack.pop()
        if best is not None and bound >= best[0]:
            continue
        frac = _first_fractional(t)
        if frac is None:
            val = t.objective_value()
            if best is None or val < best[0]:
                best = (val, t.entity_values())
            continue
        nodes += 1
        if nodes > _BB_NODE_LIMIT:
            raise PivotLimit("branch & bound node limit")
        ent, v = frac
        fl = v.numerator // v.denominator
        children = []
        right = t.copy()
        right.append_row({ent: Fraction(1)}, Fraction(-(fl + 1)))  # x ≥ fl+1
        children.append(right)
        left = t
        left.append_row({ent: Fraction(-1)}, Fraction(fl))         # x ≤ fl
        children.append(left)
        for child in children:   # left pushed last → explored first
            if not child.make_feasible():
                continue
            try:
                cv = child.optimize()
            except Unbounded:     # cannot happen under a bounded root
                continue
            if best is None or cv < best[0]:
                stack.append((child, cv))
    if best is None:
        return None
    return best


# ---------------------------------------------------------------------------
# the ILPProblem-facing API
# ---------------------------------------------------------------------------

def _entity_objective(comp: LexCompiled, objective) -> Tuple[Dict[int, Fraction], Fraction]:
    order = comp._name_idx
    coefs: Dict[int, Fraction] = {}
    const = Fraction(objective.get(1, 0))
    for k, c in objective.items():
        if k == 1 or not c:
            continue
        spec = comp.cols[order[k]]
        if spec[0] == "one":
            _, ent, lb = spec
            const += c * lb
            coefs[ent] = coefs.get(ent, Fraction(0)) + c
        else:
            entp, entn = spec[1], spec[2]
            coefs[entp] = coefs.get(entp, Fraction(0)) + c
            coefs[entn] = coefs.get(entn, Fraction(0)) - c
    return coefs, const


def _solution_from_entities(comp: LexCompiled, vals: Dict[int, Fraction],
                            names=None) -> Dict[str, Fraction]:
    out: Dict[str, Fraction] = {}
    order = comp._name_idx
    for name in (comp.col_names if names is None else names):
        spec = comp.cols[order[name]]
        if spec[0] == "one":
            _, ent, lb = spec
            out[name] = lb + vals.get(ent, Fraction(0))
        else:
            entp, entn = spec[1], spec[2]
            out[name] = vals.get(entp, Fraction(0)) - vals.get(entn, Fraction(0))
    return out


def _compiled(prob) -> LexCompiled:
    comp = getattr(prob, "_lex", None)
    if comp is None:
        comp = prob._lex = LexCompiled()
    comp.sync(prob)
    return comp


def solve_min(prob, objective, want=None):
    """Exact integer minimum of one objective (ILPProblem entry point).
    Returns ``(value, solution)`` or None; raises Unbounded."""
    comp = _compiled(prob)
    tab = comp.tableau()
    coefs, const = _entity_objective(comp, objective)
    res = ilp_min(tab, coefs, const)
    prob.last_pivots = getattr(prob, "last_pivots", 0) + tab.pivots
    if res is None:
        return None
    val, vals = res
    names = None
    if want is not None:
        names = {k for k in objective if k != 1}
        names.update(k for k in want if k in prob.vars)
    return val, _solution_from_entities(comp, vals, names)


def _stage_box(prob, obj):
    lo = hi = Fraction(obj.get(1, 0))
    for k, c in obj.items():
        if k == 1 or not c:
            continue
        v = prob.vars[k]
        lo += c * (v.lb if c > 0 else v.ub)
        hi += c * (v.ub if c > 0 else v.lb)
    return lo, hi


def _combinable(prob, obj) -> bool:
    for k, c in obj.items():
        if k == 1 or not c:
            continue
        if c.denominator != 1:
            return False
        v = prob.vars[k]
        if (not v.integer or v.lb is None or v.ub is None
                or v.lb.denominator != 1 or v.ub.denominator != 1):
            return False
    return True


def _combine_suffix(prob, stages):
    """Collapse the maximal all-integer box-bounded suffix of ``stages``
    into one exactly weighted objective (no weight cap: arithmetic is
    exact, so the weights may grow as large as the boxes require)."""
    n = len(stages)
    if n < 2 or not _combinable(prob, stages[-1]):
        return list(stages), None
    combined = dict(stages[-1])
    clo, chi = _stage_box(prob, combined)
    first = n - 1
    while first > 0 and _combinable(prob, stages[first - 1]):
        w = chi - clo + 1
        stage = stages[first - 1]
        slo, shi = _stage_box(prob, stage)
        for k, c in stage.items():
            combined[k] = combined.get(k, Fraction(0)) + w * c
        clo, chi = w * slo + clo, w * shi + chi
        first -= 1
    if first == n - 1:
        return list(stages), None
    return list(stages[:first]), combined


def lexmin(prob, objectives, want=None, canon=None):
    """Exact lexicographic minimization with a canonical tie-break.

    ``canon`` lists variables whose values must be reproducible across
    *any* solver run: after the caller's objectives they are minimized
    lexicographically in the given order, which makes the optimum unique
    on those variables.  ``None`` canonicalizes every box-bounded
    integer variable in declaration order.  ``want`` limits which
    variables are materialized in the returned dict (plus objective and
    canon variables)."""
    comp = _compiled(prob)
    tab = comp.tableau()
    objectives = list(objectives) if objectives else [{}]
    if canon is None:
        canon = [n for n, v in prob.vars.items()
                 if v.integer and v.lb is not None and v.ub is not None]
    canon = [v for v in canon if v in prob.vars]
    stages = [dict(o) for o in objectives]
    stages += [{v: Fraction(1)} for v in canon]
    head, combined = _combine_suffix(prob, stages)
    seq = head + ([combined] if combined is not None else [])
    prob.stages_skipped = 0
    cur: Optional[Dict[int, Fraction]] = None

    def value_at(obj, vals):
        coefs, const = _entity_objective(comp, obj)
        v = const
        for ent, c in coefs.items():
            v += c * vals.get(ent, Fraction(0))
        return v

    for si, obj in enumerate(seq):
        last = si == len(seq) - 1
        coefs, const = _entity_objective(comp, obj)
        val = None
        if cur is not None:
            bound = prob._objective_lower_bound(obj)
            if bound is not None and value_at(obj, cur) == bound:
                val = bound
                prob.stages_skipped += 1
        if val is None:
            res = ilp_min(tab, coefs, const)
            if res is None:
                # later stages keep the previous optimum feasible (its
                # fixing row holds with equality) — only stage 0 can be
                # genuinely infeasible
                prob.last_pivots = getattr(prob, "last_pivots", 0) + tab.pivots
                return None
            val, cur = res
        if not last:
            # fix this stage: obj ≤ val (obj ≥ val implied by optimality
            # for every integer point — the one-sided row keeps the
            # dictionary small and never cuts the incumbent)
            tab.append_row({e: -c for e, c in coefs.items()}, val - const)
    prob.last_pivots = getattr(prob, "last_pivots", 0) + tab.pivots
    names = None
    if want is not None:
        names = set(canon)
        names.update(k for k in want if k in prob.vars)
        for obj in objectives:
            names.update(k for k in obj if k != 1)
    return _solution_from_entities(comp, cur, names)
