"""PolyTOPS configuration interfaces (paper §III-A/B/C).

Two interfaces, mirroring the paper:

* **JSON** (static): ``SchedulerConfig.from_json(dict_or_path)``
  understands the keys shown in paper Listing 2 —
  ``scheduling_strategy.new_variables``, ``ILP_construction`` (per-dim
  ``cost_functions``), ``custom_constraints``, ``fusion``
  (``scheduling_dimension``/``total_distribution``/``stmts_fusion``),
  ``directives`` and ``auto_vectorization``.
* **Python callback** (dynamic, ≙ the paper's C++ dynamic-library
  interface): a callable invoked before each scheduling iteration with
  the full scheduler state; it returns the :class:`DimConfig` to use for
  that dimension (see :func:`isl_style` for the paper's Listing 3).

Predefined strategies: :func:`pluto_style`, :func:`tensor_style`,
:func:`feautrier_style`, :func:`isl_style`, :func:`bigloops_style`.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Union

#: cost functions the ILP constructor understands (paper §III-A1);
#: configs may additionally reference their own ``new_variables``
KNOWN_COST_FUNCTIONS = ("proximity", "feautrier", "contiguity",
                        "bigLoopsFirst")
FUSION_MODES = ("smart", "max", "no")
DIRECTIVE_TYPES = ("vectorize", "parallel", "sequential")


class ConfigError(ValueError):
    """Malformed JSON configuration (paper Listing 2 interface)."""


@dataclass
class DimConfig:
    """ILP construction recipe for one scheduling dimension."""
    cost_functions: List[str] = field(default_factory=lambda: ["proximity"])
    constraints: List[str] = field(default_factory=list)
    require_parallel: bool = False      # isl-style: demand a parallel dim


@dataclass
class FusionSpec:
    dimension: Union[int, str]           # dim index or 'default'
    total_distribution: bool = False
    groups: Optional[List[List[int]]] = None   # explicit statement groups


@dataclass
class Directive:
    type: str            # 'vectorize' | 'parallel' | 'sequential'
    stmts: List[int]
    iterator: Optional[int] = None       # iterator index (depth) in the stmt


@dataclass
class SchedulerConfig:
    new_variables: List[str] = field(default_factory=list)
    ilp: Dict[Union[int, str], DimConfig] = field(default_factory=dict)
    custom_constraints: Dict[Union[int, str], List[str]] = field(default_factory=dict)
    fusion: List[FusionSpec] = field(default_factory=list)
    directives: List[Directive] = field(default_factory=list)
    auto_vectorize: bool = False
    fusion_mode: str = "smart"           # 'smart' | 'max' | 'no'
    coeff_bound: int = 4
    cst_bound: int = 32
    # paper §IV-C (doitgen): parametric shifting is off by default (as in
    # Pluto); enabling it allows nonzero parameter coefficients in φ
    parametric_shift: bool = False
    # the "C++ interface": called before each iteration; wins over `ilp`
    strategy: Optional[Callable[[Any], DimConfig]] = None
    name: str = "custom"

    # -- resolution --------------------------------------------------------
    def dim_config(self, dim: int, state: Any = None) -> DimConfig:
        if self.strategy is not None and state is not None:
            return self.strategy(state)
        dc = self.ilp.get(dim, self.ilp.get("default", DimConfig()))
        extra = self.custom_constraints.get(dim, self.custom_constraints.get("default", []))
        if extra:
            dc = DimConfig(dc.cost_functions, list(dc.constraints) + list(extra),
                           dc.require_parallel)
        return dc

    def fusion_for(self, dim: int) -> Optional[FusionSpec]:
        for f in self.fusion:
            if f.dimension == dim:
                return f
        for f in self.fusion:
            if f.dimension == "default":
                return f
        return None

    # -- JSON --------------------------------------------------------------
    @staticmethod
    def _dim_key(entry: dict, what: str) -> Union[int, str]:
        dim = entry.get("scheduling_dimension", "default")
        if dim == "default":
            return dim
        if isinstance(dim, bool) or not isinstance(dim, int) or dim < 0:
            raise ConfigError(
                f"{what}: scheduling_dimension must be a non-negative "
                f"integer or 'default', got {dim!r}")
        return dim

    @staticmethod
    def _entries(strat: dict, key: str) -> List[dict]:
        val = strat.get(key, [])
        if not isinstance(val, list):
            raise ConfigError(f"{key} must be a list, got {type(val).__name__}")
        for entry in val:
            if not isinstance(entry, dict):
                raise ConfigError(
                    f"{key} entries must be objects, got {entry!r}")
        return val

    @classmethod
    def from_json(cls, src: Union[str, dict]) -> "SchedulerConfig":
        """Parse the paper-Listing-2 JSON interface.

        ``src`` is a dict (optionally wrapped in ``scheduling_strategy``)
        or a path to a JSON file.  Malformed input raises
        :class:`ConfigError` (a ``ValueError``) with a message naming the
        offending key — never a bare ``KeyError``/``TypeError`` from deep
        inside the scheduler."""
        if isinstance(src, str):
            with open(src) as f:
                data = json.load(f)
        else:
            data = src
        if not isinstance(data, dict):
            raise ConfigError(
                f"configuration must be a JSON object, got {type(data).__name__}")
        strat = data.get("scheduling_strategy", data)
        if not isinstance(strat, dict):
            raise ConfigError("scheduling_strategy must be a JSON object")
        cfg = cls()
        nv = strat.get("new_variables", [])
        if not isinstance(nv, list) or not all(isinstance(v, str) for v in nv):
            raise ConfigError("new_variables must be a list of strings")
        cfg.new_variables = list(nv)
        for entry in cls._entries(strat, "ILP_construction"):
            dim = cls._dim_key(entry, "ILP_construction")
            cfs = entry.get("cost_functions", ["proximity"])
            if not isinstance(cfs, list) or not cfs:
                raise ConfigError(
                    f"ILP_construction[{dim}]: cost_functions must be a "
                    f"non-empty list")
            for cf in cfs:
                if cf not in KNOWN_COST_FUNCTIONS and cf not in cfg.new_variables:
                    raise ConfigError(
                        f"ILP_construction[{dim}]: unknown cost function "
                        f"{cf!r} (known: {', '.join(KNOWN_COST_FUNCTIONS)}, "
                        f"plus declared new_variables)")
            cons = entry.get("constraints", [])
            if not isinstance(cons, list) or not all(isinstance(c, str) for c in cons):
                raise ConfigError(
                    f"ILP_construction[{dim}]: constraints must be a list "
                    f"of strings")
            cfg.ilp[dim] = DimConfig(
                cost_functions=list(cfs),
                constraints=list(cons),
                require_parallel=bool(entry.get("require_parallel", False)),
            )
        for entry in cls._entries(strat, "custom_constraints"):
            dim = cls._dim_key(entry, "custom_constraints")
            cons = entry.get("constraints", [])
            if not isinstance(cons, list) or not all(isinstance(c, str) for c in cons):
                raise ConfigError(
                    f"custom_constraints[{dim}]: constraints must be a "
                    f"list of strings")
            cfg.custom_constraints.setdefault(dim, []).extend(cons)
        for entry in cls._entries(strat, "fusion"):
            dim = entry.get("scheduling_dimension", 0)
            if dim != "default" and (isinstance(dim, bool)
                                     or not isinstance(dim, int) or dim < 0):
                raise ConfigError(
                    f"fusion: scheduling_dimension must be a non-negative "
                    f"integer or 'default', got {dim!r}")
            groups = entry.get("stmts_fusion")
            if groups is not None:
                if not isinstance(groups, list):
                    raise ConfigError("fusion: stmts_fusion must be a list "
                                      "of statement-index lists")
                try:
                    groups = [[int(x) for x in g] for g in groups]
                except (TypeError, ValueError):
                    raise ConfigError(
                        "fusion: stmts_fusion groups must contain "
                        "statement indices") from None
                flat = [i for g in groups for i in g]
                if len(flat) != len(set(flat)):
                    raise ConfigError(
                        "fusion: stmts_fusion groups must be disjoint "
                        f"(got {groups})")
            cfg.fusion.append(
                FusionSpec(
                    dimension=dim,
                    total_distribution=bool(entry.get("total_distribution", False)),
                    groups=groups,
                )
            )
        for entry in cls._entries(strat, "directives"):
            dtype = entry.get("type")
            if dtype not in DIRECTIVE_TYPES:
                raise ConfigError(
                    f"directives: type must be one of {DIRECTIVE_TYPES}, "
                    f"got {dtype!r}")
            stmts = entry.get("stmts", [])
            if isinstance(stmts, (str, int)):
                stmts = [stmts]
            try:
                stmts = [int(x) for x in stmts]
            except (TypeError, ValueError):
                raise ConfigError(
                    f"directives[{dtype}]: stmts must be statement "
                    f"indices, got {entry.get('stmts')!r}") from None
            it = entry.get("iterator")
            if it is not None:
                try:
                    it = int(it)
                except (TypeError, ValueError):
                    raise ConfigError(
                        f"directives[{dtype}]: iterator must be an integer "
                        f"depth or null, got {it!r}") from None
            cfg.directives.append(Directive(dtype, stmts, it))
        cfg.auto_vectorize = bool(strat.get("auto_vectorization", False))
        fm = strat.get("fusion_mode", "smart")
        if fm not in FUSION_MODES:
            raise ConfigError(
                f"fusion_mode must be one of {FUSION_MODES}, got {fm!r}")
        cfg.fusion_mode = fm
        for key, default in (("coeff_bound", 4), ("cst_bound", 32)):
            val = strat.get(key, default)
            if isinstance(val, bool) or not isinstance(val, int) or val < 1:
                raise ConfigError(
                    f"{key} must be a positive integer, got {val!r}")
            setattr(cfg, key, val)
        cfg.parametric_shift = bool(strat.get("parametric_shift", False))
        cfg.name = strat.get("name", "json")
        return cfg

    def to_json(self) -> dict:
        """Listing-2 JSON rendering; loses only the Python ``strategy``
        callback — ``from_json(to_json(cfg))`` reproduces every other
        field exactly (the config round-trip conformance invariant)."""
        out: Dict[str, Any] = {"scheduling_strategy": {}}
        s = out["scheduling_strategy"]
        if self.new_variables:
            s["new_variables"] = self.new_variables
        s["ILP_construction"] = [
            {
                "scheduling_dimension": dim,
                "cost_functions": dc.cost_functions,
                **({"constraints": dc.constraints} if dc.constraints else {}),
                **({"require_parallel": True} if dc.require_parallel else {}),
            }
            for dim, dc in self.ilp.items()
        ]
        if self.custom_constraints:
            s["custom_constraints"] = [
                {"scheduling_dimension": dim, "constraints": list(cons)}
                for dim, cons in self.custom_constraints.items()
            ]
        if self.fusion:
            s["fusion"] = [
                {
                    "scheduling_dimension": f.dimension,
                    "total_distribution": f.total_distribution,
                    **({"stmts_fusion": f.groups} if f.groups else {}),
                }
                for f in self.fusion
            ]
        if self.directives:
            s["directives"] = [
                {"type": d.type, "stmts": d.stmts, "iterator": d.iterator}
                for d in self.directives
            ]
        if self.auto_vectorize:
            s["auto_vectorization"] = True
        s["fusion_mode"] = self.fusion_mode
        s["coeff_bound"] = self.coeff_bound
        s["cst_bound"] = self.cst_bound
        if self.parametric_shift:
            s["parametric_shift"] = True
        s["name"] = self.name
        return out


# ---------------------------------------------------------------------------
# predefined strategies (paper §IV: pluto-style, tensor-scheduler-style,
# isl-style, feautrier-style, bigLoopsFirst)
# ---------------------------------------------------------------------------

def pluto_style(**kw) -> SchedulerConfig:
    cfg = SchedulerConfig(name="pluto-style", **kw)
    cfg.ilp["default"] = DimConfig(cost_functions=["proximity"])
    return cfg


def tensor_style(**kw) -> SchedulerConfig:
    """contiguity first, proximity second, no skewing (paper Listing 5)."""
    cfg = SchedulerConfig(name="tensor-style", **kw)
    cfg.ilp["default"] = DimConfig(
        cost_functions=["contiguity", "proximity"], constraints=["no-skewing"]
    )
    return cfg


def feautrier_style(**kw) -> SchedulerConfig:
    cfg = SchedulerConfig(name="feautrier-style", **kw)
    cfg.ilp["default"] = DimConfig(cost_functions=["feautrier"])
    return cfg


def bigloops_style(**kw) -> SchedulerConfig:
    cfg = SchedulerConfig(name="bigloops-style", **kw)
    cfg.ilp["default"] = DimConfig(cost_functions=["bigLoopsFirst", "proximity"])
    return cfg


def isl_style(**kw) -> SchedulerConfig:
    """Paper Listing 3: Pluto-style by default; when proximity fails to
    extract parallelism at the start of a band, recompute the dimension
    with the Feautrier cost function (dynamic strategy — this is the
    Python analogue of the C++ configuration interface)."""

    def strategy(state) -> DimConfig:
        if state.parallel_failed:
            return DimConfig(cost_functions=["feautrier"])
        if state.band_start:
            return DimConfig(cost_functions=["proximity"], require_parallel=True)
        return DimConfig(cost_functions=["proximity"])

    cfg = SchedulerConfig(name="isl-style", strategy=strategy, **kw)
    return cfg


STRATEGIES: Dict[str, Callable[..., SchedulerConfig]] = {
    "pluto": pluto_style,
    "tensor": tensor_style,
    "feautrier": feautrier_style,
    "isl": isl_style,
    "bigloops": bigloops_style,
}
