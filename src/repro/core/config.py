"""PolyTOPS configuration interfaces (paper §III-A/B/C).

Two interfaces, mirroring the paper:

* **JSON** (static): ``SchedulerConfig.from_json(dict_or_path)``
  understands the keys shown in paper Listing 2 —
  ``scheduling_strategy.new_variables``, ``ILP_construction`` (per-dim
  ``cost_functions``), ``custom_constraints``, ``fusion``
  (``scheduling_dimension``/``total_distribution``/``stmts_fusion``),
  ``directives`` and ``auto_vectorization``.
* **Python callback** (dynamic, ≙ the paper's C++ dynamic-library
  interface): a callable invoked before each scheduling iteration with
  the full scheduler state; it returns the :class:`DimConfig` to use for
  that dimension (see :func:`isl_style` for the paper's Listing 3).

Predefined strategies: :func:`pluto_style`, :func:`tensor_style`,
:func:`feautrier_style`, :func:`isl_style`, :func:`bigloops_style`.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Union


@dataclass
class DimConfig:
    """ILP construction recipe for one scheduling dimension."""
    cost_functions: List[str] = field(default_factory=lambda: ["proximity"])
    constraints: List[str] = field(default_factory=list)
    require_parallel: bool = False      # isl-style: demand a parallel dim


@dataclass
class FusionSpec:
    dimension: Union[int, str]           # dim index or 'default'
    total_distribution: bool = False
    groups: Optional[List[List[int]]] = None   # explicit statement groups


@dataclass
class Directive:
    type: str            # 'vectorize' | 'parallel' | 'sequential'
    stmts: List[int]
    iterator: Optional[int] = None       # iterator index (depth) in the stmt


@dataclass
class SchedulerConfig:
    new_variables: List[str] = field(default_factory=list)
    ilp: Dict[Union[int, str], DimConfig] = field(default_factory=dict)
    custom_constraints: Dict[Union[int, str], List[str]] = field(default_factory=dict)
    fusion: List[FusionSpec] = field(default_factory=list)
    directives: List[Directive] = field(default_factory=list)
    auto_vectorize: bool = False
    fusion_mode: str = "smart"           # 'smart' | 'max' | 'no'
    coeff_bound: int = 4
    cst_bound: int = 32
    # paper §IV-C (doitgen): parametric shifting is off by default (as in
    # Pluto); enabling it allows nonzero parameter coefficients in φ
    parametric_shift: bool = False
    # the "C++ interface": called before each iteration; wins over `ilp`
    strategy: Optional[Callable[[Any], DimConfig]] = None
    name: str = "custom"

    # -- resolution --------------------------------------------------------
    def dim_config(self, dim: int, state: Any = None) -> DimConfig:
        if self.strategy is not None and state is not None:
            return self.strategy(state)
        dc = self.ilp.get(dim, self.ilp.get("default", DimConfig()))
        extra = self.custom_constraints.get(dim, self.custom_constraints.get("default", []))
        if extra:
            dc = DimConfig(dc.cost_functions, list(dc.constraints) + list(extra),
                           dc.require_parallel)
        return dc

    def fusion_for(self, dim: int) -> Optional[FusionSpec]:
        for f in self.fusion:
            if f.dimension == dim:
                return f
        for f in self.fusion:
            if f.dimension == "default":
                return f
        return None

    # -- JSON --------------------------------------------------------------
    @classmethod
    def from_json(cls, src: Union[str, dict]) -> "SchedulerConfig":
        if isinstance(src, str):
            with open(src) as f:
                data = json.load(f)
        else:
            data = src
        strat = data.get("scheduling_strategy", data)
        cfg = cls()
        cfg.new_variables = list(strat.get("new_variables", []))
        for entry in strat.get("ILP_construction", []):
            dim = entry.get("scheduling_dimension", "default")
            cfg.ilp[dim] = DimConfig(
                cost_functions=list(entry.get("cost_functions", ["proximity"])),
                constraints=list(entry.get("constraints", [])),
                require_parallel=bool(entry.get("require_parallel", False)),
            )
        for entry in strat.get("custom_constraints", []):
            dim = entry.get("scheduling_dimension", "default")
            cfg.custom_constraints.setdefault(dim, []).extend(entry.get("constraints", []))
        for entry in strat.get("fusion", []):
            groups = entry.get("stmts_fusion")
            if groups is not None:
                groups = [[int(x) for x in g] for g in groups]
            cfg.fusion.append(
                FusionSpec(
                    dimension=entry.get("scheduling_dimension", 0),
                    total_distribution=bool(entry.get("total_distribution", False)),
                    groups=groups,
                )
            )
        for entry in strat.get("directives", []):
            stmts = entry.get("stmts", [])
            if isinstance(stmts, (str, int)):
                stmts = [int(stmts)]
            else:
                stmts = [int(x) for x in stmts]
            it = entry.get("iterator")
            cfg.directives.append(
                Directive(entry["type"], stmts, None if it is None else int(it))
            )
        cfg.auto_vectorize = bool(strat.get("auto_vectorization", False))
        cfg.fusion_mode = strat.get("fusion_mode", "smart")
        cfg.coeff_bound = int(strat.get("coeff_bound", 4))
        cfg.parametric_shift = bool(strat.get("parametric_shift", False))
        cfg.name = strat.get("name", "json")
        return cfg

    def to_json(self) -> dict:
        out: Dict[str, Any] = {"scheduling_strategy": {}}
        s = out["scheduling_strategy"]
        if self.new_variables:
            s["new_variables"] = self.new_variables
        s["ILP_construction"] = [
            {
                "scheduling_dimension": dim,
                "cost_functions": dc.cost_functions,
                **({"constraints": dc.constraints} if dc.constraints else {}),
                **({"require_parallel": True} if dc.require_parallel else {}),
            }
            for dim, dc in self.ilp.items()
        ]
        if self.fusion:
            s["fusion"] = [
                {
                    "scheduling_dimension": f.dimension,
                    "total_distribution": f.total_distribution,
                    **({"stmts_fusion": f.groups} if f.groups else {}),
                }
                for f in self.fusion
            ]
        if self.directives:
            s["directives"] = [
                {"type": d.type, "stmts": d.stmts, "iterator": d.iterator}
                for d in self.directives
            ]
        if self.auto_vectorize:
            s["auto_vectorization"] = True
        s["fusion_mode"] = self.fusion_mode
        s["name"] = self.name
        return out


# ---------------------------------------------------------------------------
# predefined strategies (paper §IV: pluto-style, tensor-scheduler-style,
# isl-style, feautrier-style, bigLoopsFirst)
# ---------------------------------------------------------------------------

def pluto_style(**kw) -> SchedulerConfig:
    cfg = SchedulerConfig(name="pluto-style", **kw)
    cfg.ilp["default"] = DimConfig(cost_functions=["proximity"])
    return cfg


def tensor_style(**kw) -> SchedulerConfig:
    """contiguity first, proximity second, no skewing (paper Listing 5)."""
    cfg = SchedulerConfig(name="tensor-style", **kw)
    cfg.ilp["default"] = DimConfig(
        cost_functions=["contiguity", "proximity"], constraints=["no-skewing"]
    )
    return cfg


def feautrier_style(**kw) -> SchedulerConfig:
    cfg = SchedulerConfig(name="feautrier-style", **kw)
    cfg.ilp["default"] = DimConfig(cost_functions=["feautrier"])
    return cfg


def bigloops_style(**kw) -> SchedulerConfig:
    cfg = SchedulerConfig(name="bigloops-style", **kw)
    cfg.ilp["default"] = DimConfig(cost_functions=["bigLoopsFirst", "proximity"])
    return cfg


def isl_style(**kw) -> SchedulerConfig:
    """Paper Listing 3: Pluto-style by default; when proximity fails to
    extract parallelism at the start of a band, recompute the dimension
    with the Feautrier cost function (dynamic strategy — this is the
    Python analogue of the C++ configuration interface)."""

    def strategy(state) -> DimConfig:
        if state.parallel_failed:
            return DimConfig(cost_functions=["feautrier"])
        if state.band_start:
            return DimConfig(cost_functions=["proximity"], require_parallel=True)
        return DimConfig(cost_functions=["proximity"])

    cfg = SchedulerConfig(name="isl-style", strategy=strategy, **kw)
    return cfg


STRATEGIES: Dict[str, Callable[..., SchedulerConfig]] = {
    "pluto": pluto_style,
    "tensor": tensor_style,
    "feautrier": feautrier_style,
    "isl": isl_style,
    "bigloops": bigloops_style,
}
