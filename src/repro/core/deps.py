"""Polyhedral dependence analysis (paper §II-A2).

For every pair of accesses to the same array (at least one write) and
every common-loop depth, a candidate dependence polyhedron is built over
(source iters s0.., target iters t0.., params) and kept if rationally
feasible (a conservative over-approximation — spurious dependences only
restrict the schedule, never break legality).

Dependence polyhedra are *per-depth*, which lets the scheduler remove
them individually once strongly satisfied (Algorithm 1's
RemoveSatisfiedDependencies).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from fractions import Fraction
from typing import Dict, List, Optional, Sequence

from .affine import Affine, affine_sub
from .polyhedron import Constraint, feasible, maximum, minimum
from .scop import Scop, Statement


@dataclass
class Dependence:
    id: int
    source: Statement
    target: Statement
    depth: int                    # loop level carrying the candidate dep
    loop_independent: bool        # textual-order dep at equal iterations
    cons: List[Constraint]        # over s*, t*, params
    kind: str                     # 'flow' | 'anti' | 'output'
    array: str
    satisfied_at: Optional[int] = None   # schedule dim that strongly satisfies
    # lazily-built CompiledPolyhedron over cons (see compiled_poly());
    # excluded from pickling so cached Schedules stay lean
    _compiled: Optional[object] = field(default=None, repr=False, compare=False)

    def src_var(self, k: int) -> str:
        return f"s{k}"

    def tgt_var(self, k: int) -> str:
        return f"t{k}"

    def __getstate__(self):
        state = dict(self.__dict__)
        state["_compiled"] = None
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)

    def __repr__(self):
        s = f"dep#{self.id} {self.kind} {self.array} S{self.source.index}->S{self.target.index} d={self.depth}"
        if self.loop_independent:
            s += " (li)"
        return s


def _rename(expr: Affine, iters: Sequence[str], prefix: str) -> Affine:
    out: Affine = {}
    pos = {it: i for i, it in enumerate(iters)}
    for k, v in expr.items():
        if k in pos:
            out[f"{prefix}{pos[k]}"] = v
        else:
            out[k] = out.get(k, Fraction(0)) + v if k in out else v
    return out


def _domain_cons(stmt: Statement, prefix: str) -> List[Constraint]:
    return [(_rename(e, stmt.iters, prefix), k) for e, k in stmt.domain]


def _param_context(scop: Scop) -> List[Constraint]:
    return scop.param_min_rows()


def compute_dependences(scop: Scop) -> List[Dependence]:
    deps: List[Dependence] = []
    stmts = scop.statements
    ctx = _param_context(scop)
    did = 0
    for s in stmts:
        for r in stmts:
            # we only build deps s -> r where s executes before r; both
            # directions are covered because (s, r) iterates all pairs.
            for a in s.accesses:
                for b in r.accesses:
                    if a.array != b.array:
                        continue
                    if not (a.is_write or b.is_write):
                        continue
                    kind = (
                        "flow" if a.is_write and not b.is_write
                        else "anti" if not a.is_write and b.is_write
                        else "output"
                    )
                    deps.extend(
                        _deps_for_pair(scop, s, r, a, b, kind, ctx, start_id=did + len(deps))
                    )
    for i, d in enumerate(deps):
        d.id = i
    return deps


def tighten_equalities(cons: List[Constraint]) -> List[Constraint]:
    """Integer tightening of equalities: if  g·X + R == 0  with the range
    of R over the polyhedron strictly inside (−g, g) and every X-term
    coefficient divisible by g, then X == 0 and R == 0 separately.

    Closes the rational-relaxation gap for linearized subscripts like
    ``b[j, 16*l + kv]`` (kv ∈ [0,16)): without it, l₁ == l₂ is not
    rationally implied and zero-distance (parallelism/coincidence) tests
    fail (paper §IV-A operators are exactly of this shape)."""
    cons = [(dict(e), k) for e, k in cons]
    changed = True
    while changed:
        changed = False
        for i, (expr, kind) in enumerate(cons):
            if kind != "==0":
                continue
            coeffs = {k: v for k, v in expr.items() if k != 1 and v != 0}
            if len(coeffs) < 2:
                continue
            g = max(abs(v) for v in coeffs.values())
            if g <= 1:
                continue
            d_part = {k: v for k, v in coeffs.items() if v % g == 0}
            r_part = {k: v for k, v in expr.items() if k == 1 or (k in coeffs and v % g != 0)}
            if not d_part or not any(k != 1 for k in r_part):
                continue
            rest = [c for j, c in enumerate(cons) if j != i]
            lo = minimum(rest, r_part)
            hi = maximum(rest, r_part)
            if lo is None or hi is None:
                continue
            if lo > -g and hi < g:
                cons[i] = (d_part, "==0")
                cons.append((r_part, "==0"))
                changed = True
                break
    return cons


def _deps_for_pair(scop, s, r, a, b, kind, ctx, start_id) -> List[Dependence]:
    out: List[Dependence] = []
    ncommon = scop.common_loops(s, r)
    base: List[Constraint] = []
    base += _domain_cons(s, "s")
    base += _domain_cons(r, "t")
    base += ctx
    # subscript equality
    for sub_a, sub_b in zip(a.subscripts, b.subscripts):
        ea = _rename(sub_a, s.iters, "s")
        eb = _rename(sub_b, r.iters, "t")
        base.append((affine_sub(ea, eb), "==0"))
    base = tighten_equalities(base)
    # carried deps at each common depth
    for depth in range(ncommon):
        cons = [(dict(e), k) for e, k in base]
        for k in range(depth):
            cons.append(({f"s{k}": Fraction(1), f"t{k}": Fraction(-1)}, "==0"))
        cons.append(({f"t{depth}": Fraction(1), f"s{depth}": Fraction(-1), 1: Fraction(-1)}, ">=0"))
        if feasible(cons):
            out.append(Dependence(start_id + len(out), s, r, depth, False, cons, kind, a.array))
    # loop-independent dep (equal common iterations, textual order)
    if (s is not r and scop.textually_before(s, r)) or (s is not r and ncommon == min(s.dim, r.dim) and scop.textually_before(s, r)):
        cons = [(dict(e), k) for e, k in base]
        for k in range(ncommon):
            cons.append(({f"s{k}": Fraction(1), f"t{k}": Fraction(-1)}, "==0"))
        if feasible(cons):
            out.append(Dependence(start_id + len(out), s, r, ncommon, True, cons, kind, a.array))
    return out


# ---------------------------------------------------------------------------
# schedule-row evaluation over a dependence
# ---------------------------------------------------------------------------

def compiled_poly(dep: Dependence, params: Sequence[str]):
    """The dependence polyhedron compiled once per Dependence (numeric LP
    matrices cached), reused for every distance/satisfaction query across
    all scheduling dimensions."""
    if dep._compiled is None:
        from .polyhedron import CompiledPolyhedron

        extra = [f"s{k}" for k in range(dep.source.dim)]
        extra += [f"t{k}" for k in range(dep.target.dim)]
        extra += list(params)
        dep._compiled = CompiledPolyhedron(dep.cons, extra)
    return dep._compiled

def phi_difference(dep: Dependence, row_src: Dict, row_tgt: Dict, params: Sequence[str]) -> Affine:
    """Affine form φ_R(t) − φ_S(s) over the dep polyhedron variables,
    given concrete schedule rows {var: Fraction} keyed by
    it<k>/par names/'1'."""
    expr: Affine = {}

    def acc(key, coef):
        if coef:
            expr[key] = expr.get(key, Fraction(0)) + coef

    for k in range(dep.target.dim):
        acc(f"t{k}", Fraction(row_tgt.get(("it", k), 0)))
    for k in range(dep.source.dim):
        acc(f"s{k}", -Fraction(row_src.get(("it", k), 0)))
    for p in params:
        acc(p, Fraction(row_tgt.get(("par", p), 0)) - Fraction(row_src.get(("par", p), 0)))
    acc(1, Fraction(row_tgt.get(("cst",), 0)) - Fraction(row_src.get(("cst",), 0)))
    return expr


def dep_distance_range(dep: Dependence, row_src, row_tgt, params, cache: bool = True):
    """(min, max) of φ_R − φ_S over the dependence polyhedron.

    ``cache=True`` optimizes over the per-dependence compiled polyhedron
    (same results, no LP rebuild); ``cache=False`` is the seed path."""
    diff = phi_difference(dep, row_src, row_tgt, params)
    if cache:
        cp = compiled_poly(dep, params)
        return cp.minimum(diff), cp.maximum(diff)
    lo = minimum(dep.cons, diff)
    hi = maximum(dep.cons, diff)
    return lo, hi


def dep_distance_min(dep: Dependence, row_src, row_tgt, params, cache: bool = True):
    """Just the minimum dependence distance (satisfaction tests) — lets
    hot callers skip the max-side LP when parallelism is already ruled
    out."""
    diff = phi_difference(dep, row_src, row_tgt, params)
    if cache:
        return compiled_poly(dep, params).minimum(diff)
    return minimum(dep.cons, diff)


def dep_distance_max(dep: Dependence, row_src, row_tgt, params, cache: bool = True):
    diff = phi_difference(dep, row_src, row_tgt, params)
    if cache:
        return compiled_poly(dep, params).maximum(diff)
    return maximum(dep.cons, diff)


def strongly_satisfied(dep: Dependence, row_src, row_tgt, params) -> bool:
    diff = phi_difference(dep, row_src, row_tgt, params)
    lo = compiled_poly(dep, params).minimum(diff)
    return lo is not None and lo >= 1


def zero_distance(dep: Dependence, row_src, row_tgt, params) -> bool:
    lo, hi = dep_distance_range(dep, row_src, row_tgt, params)
    return lo == 0 and hi == 0
