"""Backend-agnostic schedule-tree IR: one scheduler output, many emitters.

The PolyTOPS pipeline feeds *multiple* code generators (the paper pairs
the scheduler with isl and CLooG; this repo has a numpy oracle, a C
measurement backend and the Pallas/TPU kernel-plan lowering).  Before
this module each backend re-derived the same facts from the raw
``Schedule`` — loop separation, Fourier–Motzkin bounds, parallel/vector
legality.  Now everything a backend needs is computed **once** here and
recorded on an explicit tree (Tiramisu-style: transformations are named
marks on the tree, not facts re-derived per emitter):

* :class:`BandNode` — one loop dimension.  Carries the FM-derived lower/
  upper bound expressions *per statement* (affine over outer loop vars
  and parameters), the governing schedule dim, and the marks:

  - ``parallel``   — zero dependence distance (``level_parallel``),
  - ``vector``     — single-statement innermost dim legal for SIMD /
                     lane mapping (unit access strides),
  - ``tile(T)``    — a tile counter of size ``T`` inserted by postproc,
  - ``wavefront``  — the sequential wave-sum dim of a skewed band,
  - ``wave_par``   — the tile counter whose parallelism lives under a
                     wavefront (legal by band permutability).

* :class:`SequenceNode` — ordered children (scalar schedule dims /
  loop distribution; the statement-separation decision is taken here,
  once, via the dependence SCCs).
* :class:`LeafNode` — one statement instance; records which enclosing
  band dims need per-statement bound guards (mixed-bound fused loops).

The tree also carries the iterator substitution ``it = g(y*, params)``
per statement, the schedule's band ids and vectorize directives — enough
for every backend: the numpy emitter and the C emitter walk the tree
(:mod:`repro.core.codegen` / :mod:`repro.core.cbackend`), and
:func:`repro.core.akg.lower_to_kernel_plan` maps it to a Pallas
:class:`~repro.core.akg.KernelPlan`.

Trees serialize losslessly to JSON (:func:`tree_to_json` /
:func:`tree_from_json`) for the golden corpus and the schedule-cache
payload; bump :data:`TREE_VERSION` whenever construction semantics
change (the cache key includes it).

Bound context: FM chains are LP-redundancy-pruned against what is known
true at runtime.  ``concrete=False`` keeps parameters symbolic (numpy
oracle: only the SCoP's assumed parameter lower bound); ``concrete=True``
additionally assumes the SCoP's concrete parameter values (C backend,
which bakes them in as ``#define``\\ s — this is what collapses tiled/
wavefronted MINI/MAXI chains).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from fractions import Fraction
from typing import Dict, List, Optional, Sequence, Set, Tuple, Union

from .affine import Affine
from .polyhedron import Constraint, bounds_of
from .scheduler import Schedule, _scc_groups
from .scop import Scop, Statement

#: serialization/construction format version — part of the schedule
#: cache key, so cached trees can never go stale silently
TREE_VERSION = 1


# ---------------------------------------------------------------------------
# Scanning systems: per statement, dims described as equalities or
# tile inequalities over (y*, it*, params)
# ---------------------------------------------------------------------------


@dataclass
class DimSpec:
    kind: str              # 'eq' (y == phi(it, N, 1)) | 'tile'
    phi: Affine            # over stmt iterators / params / const(1)
    tile: int = 0          # tile size for kind == 'tile'
    sched_dim: int = 0     # schedule dim governing dependence satisfaction:
                           # own dim for eq rows, band start for tile/wave dims
    role: str = ""         # '' (point/eq) | 'tile' (tile counter) |
                           # 'wave' (sequential wavefront sum) |
                           # 'wave_par' (tile counter inside a wave: parallel
                           # by band permutability, see level_parallel)


@dataclass
class ScanStmt:
    stmt: Statement
    dims: List[DimSpec]
    guards: List[str] = field(default_factory=list)

    def n_dims(self) -> int:
        return len(self.dims)


def scan_from_schedule(sched: Schedule) -> List[ScanStmt]:
    out = []
    for s in sched.scop.statements:
        dims = []
        for d, row in enumerate(sched.rows[s.index]):
            phi: Affine = {}
            for (key, *rest), v in row.coeffs.items():
                if key == "it":
                    phi[s.iters[rest[0]]] = v
                elif key == "par":
                    phi[rest[0]] = v
                else:
                    phi[1] = v
            dims.append(DimSpec("eq", phi, sched_dim=d))
        out.append(ScanStmt(s, dims))
    return out


def yvar(d: int) -> str:
    # underscore avoids collisions with SCoP array/scalar names like "y1"
    return f"y_{d}"


def _full_system(ss: ScanStmt, params: Sequence[str]) -> List[Constraint]:
    """Constraints over (y*, it*, params) for one statement."""
    cons: List[Constraint] = [(dict(e), k) for e, k in ss.stmt.domain]
    for d, spec in enumerate(ss.dims):
        y = yvar(d)
        if spec.kind == "eq":
            e = dict(spec.phi)
            e[y] = e.get(y, Fraction(0)) - 1
            cons.append((e, "==0"))
        else:  # tile: T*y <= phi <= T*y + T - 1
            T = Fraction(spec.tile)
            e1 = dict(spec.phi)
            e1[y] = e1.get(y, Fraction(0)) - T
            cons.append((e1, ">=0"))                      # phi - T*y >= 0
            e2 = {k: -v for k, v in spec.phi.items()}
            e2[y] = e2.get(y, Fraction(0)) + T
            e2[1] = e2.get(1, Fraction(0)) + T - 1
            cons.append((e2, ">=0"))                      # T*y + T-1 - phi >= 0
    return cons


def iterator_substitution(ss: ScanStmt) -> Dict[str, Affine]:
    """Express each statement iterator as affine over (y*, params) by
    inverting a full-rank subset of the scan's 'eq' rows.  Shared by the
    tree builder, the cache model (tile-footprint strides) and the
    autotuner (locality scoring)."""
    from .linalg_q import inverse, mat, rank

    s = ss.stmt
    eqs = []
    for d, spec in enumerate(ss.dims):
        if spec.kind == "eq" and any(k in s.iters for k in spec.phi):
            eqs.append((d, spec.phi))
    # build T (rows over iterators) picking a full-rank subset
    rows, chosen = [], []
    for d, phi in eqs:
        row = [phi.get(it, Fraction(0)) for it in s.iters]
        if rank(mat(rows + [row])) > len(rows):
            rows.append(row)
            chosen.append((d, phi))
        if len(rows) == s.dim:
            break
    if len(rows) < s.dim:
        raise ValueError(f"schedule not invertible for {s}")
    tinv = inverse(mat(rows))
    subst: Dict[str, Affine] = {}
    for i, it in enumerate(s.iters):
        expr: Affine = {}
        for j, (d, phi) in enumerate(chosen):
            c = tinv[i][j]
            if c == 0:
                continue
            expr[yvar(d)] = expr.get(yvar(d), Fraction(0)) + c
            for k, v in phi.items():
                if k not in s.iters:   # params / const move to RHS
                    expr[k] = expr.get(k, Fraction(0)) - c * v
        subst[it] = {k: v for k, v in expr.items() if v != 0}
    return subst


def wave_parallel(group: Sequence[ScanStmt], d: int) -> bool:
    """True when scan level ``d`` is a wavefront-inner tile counter for
    every statement in the group — the one loop whose parallelism lives
    under a sequential wave dim (see level_parallel)."""
    specs = [ss.dims[d] for ss in group if d < ss.n_dims()]
    return bool(specs) and all(spec.role == "wave_par" for spec in specs)


def level_parallel(sched: Schedule, group: Sequence[ScanStmt], d: int) -> bool:
    """Single source of truth for loop-level parallel legality — the
    ``parallel`` mark of the tree, consumed by the numpy emitter
    (vectorized emission), the C backend (omp parallel/simd pragmas) and
    the Pallas plan lowering, so every backend marks the same dims.

    * wavefront sum dims are sequential by construction;
    * the tile counter inside a wavefront ('wave_par') is parallel: the
      band is fully permutable, so every active dependence has
      componentwise non-negative distance, tile counters inherit that,
      and equal wave value forces both tile deltas to zero (same tile);
    * everything else is judged against SCHEDULE dims via
      stmt_parallel_at_set (distance zero for all deps not satisfied
      outside)."""
    specs = [ss.dims[d] for ss in group if d < ss.n_dims()]
    if not specs:
        return False
    if any(spec.role == "wave" for spec in specs):
        return False
    if wave_parallel(group, d):
        return True
    stmt_set = {ss.stmt.index for ss in group if d < ss.n_dims()}
    sd = min(spec.sched_dim for spec in specs)
    return sched.stmt_parallel_at_set(stmt_set, sd)


def coeff_of_y(e: Affine, sub: Dict[str, Affine], d: int,
               params: Sequence[str]) -> Optional[Fraction]:
    """Coefficient of loop var ``y_d`` in subscript ``e`` after iterator
    substitution; None when fractional (non-unimodular)."""
    tot = Fraction(0)
    for k, v in e.items():
        if k == 1 or k in params:
            continue
        c = sub[k].get(yvar(d), Fraction(0))
        tot += v * c
    if tot.denominator != 1:
        return None
    return tot


def render_affine(e: Affine) -> Tuple[str, int]:
    """Canonical source rendering of an affine over y*/params (ints at
    runtime): ``(body, den)`` with the expression equal to body/den.
    The body is valid in both Python and C; backends wrap the division
    in their own ceil/floor idiom."""
    den = 1
    for v in e.values():
        den = den * v.denominator // math.gcd(den, v.denominator)
    parts = []
    for k, v in sorted(e.items(), key=lambda kv: str(kv[0])):
        c = int(v * den)
        if c == 0:
            continue
        if k == 1:
            parts.append(f"{c:+d}")
        elif c == 1:
            parts.append(f"+{k}")
        elif c == -1:
            parts.append(f"-{k}")
        else:
            parts.append(f"{c:+d}*{k}")
    body = "".join(parts) or "0"
    if body.startswith("+"):
        body = body[1:]
    return body, den


# ---------------------------------------------------------------------------
# tree nodes
# ---------------------------------------------------------------------------

#: per-statement loop bounds of one band dim: (lower affines, upper affines);
#: the loop var is >= ceil(max lowers) and <= floor(min uppers)
BoundPair = Tuple[List[Affine], List[Affine]]


@dataclass
class SequenceNode:
    """Ordered execution of children (scalar dims / loop distribution)."""
    children: List["Node"]


@dataclass
class BandNode:
    """One loop dimension of the scanned schedule."""
    dim: int                           # scan level; loop var is yvar(dim)
    sched_dim: int                     # governing schedule dimension
    role: str                          # '' | 'tile' | 'wave' | 'wave_par'
    tile: int                          # tile size when role == 'tile'
    parallel: bool                     # zero-distance for the group
    vector: bool                       # SIMD/lane-legal single-stmt innermost
    innermost: bool                    # no further bands below
    stmts: Tuple[int, ...]             # statements scanned by this loop
    bounds: Dict[int, BoundPair]       # per-stmt FM-derived bounds
    child: "Node"

    @property
    def marks(self) -> Tuple[str, ...]:
        """Named transformation marks (the backend vocabulary)."""
        out = []
        if self.role == "tile":
            out.append(f"tile({self.tile})")
        elif self.role == "wave":
            out.append("wavefront")
        elif self.role == "wave_par":
            out.append("wave_par")
        if self.parallel:
            out.append("parallel")
        if self.vector:
            out.append("vector")
        return tuple(out)


@dataclass
class LeafNode:
    """One statement instance; ``guards`` lists enclosing band dims whose
    per-statement bounds must be re-checked (mixed-bound fused loops)."""
    stmt: int
    guards: Tuple[int, ...] = ()


Node = Union[SequenceNode, BandNode, LeafNode]


@dataclass
class ScheduleTree:
    """Root of the IR plus everything per-statement the backends need."""
    scop: Scop                                   # not serialized (structure)
    root: Node
    n_dims: int
    params: List[str]
    subst: Dict[int, Dict[str, Affine]]          # stmt -> it = g(y*, params)
    vector_iter: Dict[int, int]                  # stmt -> directive iter idx
    sched_bands: List[int]                       # band id per schedule dim
    concrete: bool                               # bound-pruning context used
    pretty: str = ""                             # schedule text (debug)

    def bands(self) -> List[BandNode]:
        """All band nodes, outermost-first (document order)."""
        out: List[BandNode] = []

        def walk(n: Optional[Node]):
            if isinstance(n, SequenceNode):
                for c in n.children:
                    walk(c)
            elif isinstance(n, BandNode):
                out.append(n)
                walk(n.child)
        walk(self.root)
        return out


# ---------------------------------------------------------------------------
# construction
# ---------------------------------------------------------------------------


class _FakeDep:
    """Adapter so separation can reuse the scheduler's SCC machinery."""

    def __init__(self, a: int, b: int, idx):
        self.source = idx[a].stmt
        self.target = idx[b].stmt
        self.satisfied_at = None


class _TreeBuilder:
    def __init__(self, sched: Schedule, scan: Sequence[ScanStmt],
                 context: Sequence[Constraint]):
        self.sched = sched
        self.scop = sched.scop
        self.params = self.scop.param_names()
        self.scan = list(scan)
        self.n_dims = max(ss.n_dims() for ss in self.scan)
        # FM-derived bounds + iterator substitution: computed ONCE here,
        # consumed by every backend
        self.bounds: Dict[int, List[BoundPair]] = {}
        self.subst: Dict[int, Dict[str, Affine]] = {}
        for ss in self.scan:
            sys_full = _full_system(ss, self.params)
            per_dim: List[BoundPair] = []
            for d in range(ss.n_dims()):
                inner = [it for it in ss.stmt.iters] + [
                    yvar(k) for k in range(ss.n_dims() - 1, d, -1)]
                lo, hi = bounds_of(sys_full, yvar(d), inner, context=context)
                per_dim.append((lo, hi))
            self.bounds[ss.stmt.index] = per_dim
            self.subst[ss.stmt.index] = iterator_substitution(ss)

    # -- structural helpers -------------------------------------------------
    def _const_at(self, ss: ScanStmt, d: int) -> Optional[int]:
        spec = ss.dims[d]
        if spec.kind != "eq":
            return None
        if any(k in ss.stmt.iters for k in spec.phi):
            return None
        if any(k != 1 for k in spec.phi):
            return None   # parametric constant: treat as loop
        return int(spec.phi.get(1, Fraction(0)))

    def _innermost_linear(self, ss: ScanStmt, d: int) -> bool:
        for dd in range(d + 1, ss.n_dims()):
            if self._const_at(ss, dd) is None:
                return False
        return True

    def _separate(self, group: List[ScanStmt], d: int) -> List[List[ScanStmt]]:
        """Order statements into sequential loop groups; merge cyclic ones."""
        if len(group) == 1:
            return [group]
        idx = {ss.stmt.index: ss for ss in group}
        # deps that still constrain relative order at/below this level —
        # satisfaction is judged against SCHEDULE dims, not scan levels
        level_sd = min(ss.dims[d].sched_dim for ss in group if d < ss.n_dims())
        edges: Set[Tuple[int, int]] = set()
        for dep in self.sched.deps:
            a, b = dep.source.index, dep.target.index
            if a == b or a not in idx or b not in idx:
                continue
            if dep.satisfied_at is not None and dep.satisfied_at < level_sd:
                continue
            edges.add((a, b))
        # union cyclic pairs via SCC on the subgraph
        deps_like = [_FakeDep(a, b, idx) for (a, b) in edges]
        sccs = _scc_groups([ss.stmt for ss in group], deps_like)
        out = []
        for comp in sccs:
            # keep statements with *identical* loop structure together only
            # if they are in the same SCC; singleton SCCs become their own
            # sequential loop (classic distribution)
            out.append([idx[i] for i in comp if i in idx])
        return [g for g in out if g]

    def _vectorizable(self, ss: ScanStmt, d: int) -> bool:
        spec = ss.dims[d]
        if spec.kind != "eq":
            return False
        s = ss.stmt
        # schedule legality shared with every backend's parallel marking
        if not level_parallel(self.sched, [ss], d):
            return False
        # the loop variable must enter subscripts with coeff in {0, ±1}
        sub = self.subst[s.index]
        for acc in s.accesses:
            for e in acc.subscripts:
                c = coeff_of_y(e, sub, d, self.params)
                if c is None or abs(c) not in (0, 1):
                    return False
        return True

    @staticmethod
    def _bound_key(blist: List[Affine]) -> frozenset:
        """Canonical identity of a rendered bound set — two statements
        share loop bounds iff their keys are equal, in every backend
        (both render through :func:`render_affine`)."""
        return frozenset(render_affine(e) for e in blist)

    # -- recursion ----------------------------------------------------------
    def build(self) -> Node:
        return self._level(list(self.scan), 0, {})

    def _level(self, group: List[ScanStmt], d: int,
               guards: Dict[int, Tuple[int, ...]]) -> Optional[Node]:
        if not group:
            return None
        if d >= self.n_dims or all(ss.n_dims() <= d for ss in group):
            leaves: List[Node] = [
                LeafNode(ss.stmt.index, guards.get(ss.stmt.index, ()))
                for ss in sorted(group, key=lambda s: s.stmt.index)]
            return leaves[0] if len(leaves) == 1 else SequenceNode(leaves)
        consts = {ss.stmt.index: self._const_at(ss, d) for ss in group}
        if all(c is not None for c in consts.values()):
            order: Dict[int, List[ScanStmt]] = {}
            for ss in group:
                order.setdefault(consts[ss.stmt.index], []).append(ss)
            children = [self._level(order[c], d + 1, guards)
                        for c in sorted(order)]
            children = [c for c in children if c is not None]
            if not children:
                return None
            return children[0] if len(children) == 1 else SequenceNode(children)
        # linear level: separate into sequential loop groups when legal
        nodes = [self._band(sub, d, guards) for sub in self._separate(group, d)]
        return nodes[0] if len(nodes) == 1 else SequenceNode(nodes)

    def _band(self, group: List[ScanStmt], d: int,
              guards: Dict[int, Tuple[int, ...]]) -> BandNode:
        bounds = {ss.stmt.index: self.bounds[ss.stmt.index][d] for ss in group}
        lo_keys = {self._bound_key(lo) for lo, _ in bounds.values()}
        hi_keys = {self._bound_key(hi) for _, hi in bounds.values()}
        mixed = len(group) > 1 and (len(lo_keys) > 1 or len(hi_keys) > 1)
        new_guards = dict(guards)
        if mixed:
            for ss in group:
                prev = new_guards.get(ss.stmt.index, ())
                new_guards[ss.stmt.index] = prev + (d,)
        specs = [ss.dims[d] for ss in group if d < ss.n_dims()]
        roles = {spec.role for spec in specs}
        vector = (
            len(group) == 1
            and self._innermost_linear(group[0], d)
            and not new_guards.get(group[0].stmt.index)
            and self._vectorizable(group[0], d)
        )
        return BandNode(
            dim=d,
            sched_dim=min(spec.sched_dim for spec in specs),
            role=roles.pop() if len(roles) == 1 else "",
            tile=specs[0].tile,
            parallel=level_parallel(self.sched, group, d),
            vector=vector,
            innermost=all(self._innermost_linear(ss, d) for ss in group),
            stmts=tuple(sorted(bounds)),
            bounds=bounds,
            child=self._level(group, d + 1, new_guards),
        )


def build_tree(sched: Schedule, scan: Optional[Sequence[ScanStmt]] = None,
               concrete: bool = False,
               context: Optional[Sequence[Constraint]] = None) -> ScheduleTree:
    """Build the schedule tree for ``sched`` (optionally over a tiled /
    wavefronted ``scan`` from :func:`repro.core.postproc.tile_schedule`).

    ``concrete=True`` prunes FM bound chains against the SCoP's concrete
    parameter values (the C backend's context); the default keeps
    parameters symbolic (numpy oracle).  ``context`` overrides both.
    """
    scop = sched.scop
    if scan is None:
        scan = scan_from_schedule(sched)
    if context is None:
        context = scop.param_min_rows()
        if concrete:
            context = context + scop.param_rows()
    b = _TreeBuilder(sched, scan, context)
    return ScheduleTree(
        scop=scop,
        root=b.build(),
        n_dims=b.n_dims,
        params=b.params,
        subst=b.subst,
        vector_iter=dict(sched.vector_iter),
        sched_bands=list(sched.bands),
        concrete=bool(concrete),
        pretty=sched.pretty(),
    )


def schedule_tree(sched: Schedule, scan: Optional[Sequence[ScanStmt]] = None,
                  concrete: bool = False) -> ScheduleTree:
    """Like :func:`build_tree`, but the plain (untiled, parametric) tree
    is memoized on the Schedule object — repeat consumers (kernel-plan
    lowering, the numpy emitter, the golden dumps) share one FM pass,
    and the memo rides along in schedule-cache pickles (see
    :func:`repro.core.schedcache.cached_schedule_scop`)."""
    if scan is not None or concrete:
        return build_tree(sched, scan=scan, concrete=concrete)
    tree = getattr(sched, "_tree", None)
    if tree is None:
        tree = build_tree(sched)
        try:
            sched._tree = tree
        except Exception:
            pass
    return tree


# ---------------------------------------------------------------------------
# lossless JSON round-trip
# ---------------------------------------------------------------------------


def _aff_json(e: Affine) -> list:
    return [[str(k), str(Fraction(v))]
            for k, v in sorted(e.items(), key=lambda kv: str(kv[0])) if v]


def _aff_from(pairs) -> Affine:
    out: Affine = {}
    for k, v in pairs:
        out[1 if k == "1" else k] = Fraction(v)
    return out


def _node_json(node: Optional[Node]):
    if node is None:
        return None
    if isinstance(node, SequenceNode):
        return {"t": "seq", "children": [_node_json(c) for c in node.children]}
    if isinstance(node, BandNode):
        return {
            "t": "band", "dim": node.dim, "sched_dim": node.sched_dim,
            "role": node.role, "tile": node.tile,
            "parallel": node.parallel, "vector": node.vector,
            "innermost": node.innermost,
            # display-only: derived from role/tile/parallel/vector (the
            # fields above are authoritative; _node_from never reads it)
            # — kept so golden dumps show the mark vocabulary directly
            "marks": list(node.marks),
            "stmts": list(node.stmts),
            "bounds": {str(s): [[_aff_json(e) for e in lo],
                                [_aff_json(e) for e in hi]]
                       for s, (lo, hi) in sorted(node.bounds.items())},
            "child": _node_json(node.child),
        }
    return {"t": "leaf", "stmt": node.stmt, "guards": list(node.guards)}


def _node_from(data) -> Optional[Node]:
    if data is None:
        return None
    t = data["t"]
    if t == "seq":
        return SequenceNode([_node_from(c) for c in data["children"]])
    if t == "band":
        return BandNode(
            dim=data["dim"], sched_dim=data["sched_dim"], role=data["role"],
            tile=data["tile"], parallel=data["parallel"],
            vector=data["vector"], innermost=data["innermost"],
            stmts=tuple(data["stmts"]),
            bounds={int(s): ([_aff_from(e) for e in lo],
                             [_aff_from(e) for e in hi])
                    for s, (lo, hi) in data["bounds"].items()},
            child=_node_from(data["child"]),
        )
    return LeafNode(data["stmt"], tuple(data["guards"]))


def tree_to_json(tree: ScheduleTree) -> dict:
    """Plain-dict rendering of the tree; json.dumps-able, deterministic,
    and lossless (see :func:`tree_from_json`)."""
    return {
        "version": TREE_VERSION,
        "n_dims": tree.n_dims,
        "params": list(tree.params),
        "concrete": tree.concrete,
        "subst": {str(s): {it: _aff_json(e) for it, e in sorted(sub.items())}
                  for s, sub in sorted(tree.subst.items())},
        "vector_iter": {str(s): int(v)
                        for s, v in sorted(tree.vector_iter.items())},
        "sched_bands": list(tree.sched_bands),
        "pretty": tree.pretty,
        "root": _node_json(tree.root),
    }


def tree_from_json(data: dict, scop: Scop) -> ScheduleTree:
    """Inverse of :func:`tree_to_json`.  ``scop`` supplies the statement
    bodies/accesses the serialization deliberately does not duplicate."""
    if data.get("version") != TREE_VERSION:
        raise ValueError(
            f"schedule-tree format {data.get('version')!r} != {TREE_VERSION}")
    return ScheduleTree(
        scop=scop,
        root=_node_from(data["root"]),
        n_dims=data["n_dims"],
        params=list(data["params"]),
        subst={int(s): {it: _aff_from(e) for it, e in sub.items()}
               for s, sub in data["subst"].items()},
        vector_iter={int(s): int(v) for s, v in data["vector_iter"].items()},
        sched_bands=list(data["sched_bands"]),
        concrete=data["concrete"],
        pretty=data.get("pretty", ""),
    )
