"""Roofline terms per (arch × shape × mesh × variant) from dry-run
artifacts. Run `python -m repro.launch.dryrun --all` first."""
from __future__ import annotations
import json, sys
from pathlib import Path

RESULTS = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def run(out=sys.stdout):
    files = sorted(RESULTS.glob("*.json")) if RESULTS.exists() else []
    if not files:
        print("roofline,no_dryrun_artifacts_yet,0,run repro.launch.dryrun", file=out)
        return
    print("arch,shape,mesh,variant,mem_gib,compute_s,memory_s,collective_s,"
          "bottleneck,model_flops_frac,mfu_upper_bound", file=out)
    n_ok = 0
    for f in files:
        d = json.loads(f.read_text())
        if not d.get("ok"):
            print(f"{d['arch']},{d['shape']},{d['mesh']},{d['variant']},"
                  f"FAILED,,,,,,", file=out)
            continue
        r = d["roofline"]
        n_ok += 1
        print(f"{d['arch']},{d['shape']},{d['mesh']},{d['variant']},"
              f"{d['memory']['bytes_per_device']/2**30:.2f},"
              f"{r['compute_s']:.4g},{r['memory_s']:.4g},"
              f"{r['collective_s']:.4g},{r['bottleneck']},"
              f"{r['model_flops_frac']:.3f},{r['mfu_upper_bound']:.5f}",
              file=out)
    print(f"TOTAL,cells_ok,{n_ok},of {len(files)}", file=out)


if __name__ == "__main__":
    run()
