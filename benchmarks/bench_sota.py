"""Paper Fig. 4 / Table II analogue: scheduling-tool comparison.

External tools (Pluto+, Pluto-lp-dfp, isl-PPCG) are not installable in
this offline container, so each column is our faithful *reproduction of
that tool's strategy* inside PolyTOPS — exactly the paper's point that
the configurable scheduler subsumes them:

  pluto-dev      = pluto-style (proximity, smart fusion)
  pluto-lp-dfp   = pluto-style under its three fusion heuristics
                   (smart/max/no), best-of — mirrors [29]
  isl-PPCG       = isl-style (coincidence + Feautrier fallback)
  polytops-ks    = kernel-specific configuration (our contribution)

Output CSV: kernel,tool,us_per_call,speedup_vs_pluto
"""
from __future__ import annotations

import sys
from typing import List

from repro.core import config as CFG
from repro.core.deps import compute_dependences
from repro.core.scops_polybench import REGISTRY

from .common import (FAST, Measurement, Variant, check_checksums,
                     kernel_specific_variants, measure, standard_variants)

KERNELS = ["gemm", "mm3", "trmm", "symm", "trisolv", "gesummv", "bicg",
           "jacobi1d", "jacobi2d", "doitgen", "lu", "seidel2d"]
FAST_KERNELS = ["gemm", "trmm", "jacobi1d"]


def _fusion_variant(name: str, mode: str) -> Variant:
    def mk():
        cfg = CFG.pluto_style()
        cfg.fusion_mode = mode
        cfg.name = name
        return cfg
    return Variant(name, mk)


def run(out=sys.stdout):
    print("kernel,tool,us_per_call,speedup_vs_pluto", file=out)
    for name in (FAST_KERNELS if FAST else KERNELS):
        try:
            _run_kernel(name, out)
        except Exception as e:
            print(f"{name},KERNEL_FAILED,{type(e).__name__}:{e}", file=out)


def _run_kernel(name, out):
        scop = REGISTRY[name]()
        deps = compute_dependences(scop)
        base_ms = measure(scop, Variant("pluto-style", CFG.pluto_style), deps=deps)
        lp_dfp: List[Measurement] = [
            measure(scop, _fusion_variant(f"pluto-{m}fuse", m), deps=deps)
            for m in ("smart", "max", "no")
        ]
        isl_ms = measure(scop, Variant("isl-style", CFG.isl_style), deps=deps)
        ks_candidates = [base_ms, isl_ms] + [
            measure(scop, v, deps=deps)
            for v in standard_variants()[2:] + kernel_specific_variants()
        ]
        check_checksums(name, [base_ms, isl_ms] + lp_dfp + ks_candidates)
        best_lp = min(lp_dfp, key=lambda m: m.seconds)
        best_ks = min(ks_candidates, key=lambda m: m.seconds)
        rows = [("pluto-dev", base_ms), ("pluto-lp-dfp(best)", best_lp),
                ("isl-PPCG-style", isl_ms),
                (f"polytops-ks({best_ks.variant})", best_ks)]
        for tool, m in rows:
            print(f"{name},{tool},{m.seconds*1e6:.1f},"
                  f"{base_ms.seconds/m.seconds:.3f}", file=out)


if __name__ == "__main__":
    run()
