"""schedd load bench: coalescing, warm-hit latency, fallback behaviour.

Launches a real daemon subprocess on a private socket with a private
cache pool and drives it the way a compile farm would:

* **coalescing** — N clients fire the *identical* schedule request
  concurrently (the daemon holds the computation open briefly via the
  chaos-only ``test_delay_s`` field so the requests genuinely overlap);
  the daemon must run ONE computation and serve every other client from
  the shared flight.

* **warm-hit latency** — p50/p99 of a warm kernel-plan request through
  the daemon (a pre-encoded frame-cache hit: socket + handshake +
  unpickle) against the in-process disk-hit path (memo + memory tier
  cleared each rep, so ``cached_schedule_scop`` re-reads the pickle and
  the plan re-lowers).  tier1.sh gates the p50 ratio at 2x.

* **fallback** — a client pointed at a socket that does not exist must
  serve every plan in-process, counted in ``ClientStats``.

* **TCP warm-hit latency** — the same warm frame-cache hit through the
  authenticated localhost TCP transport (pooled connection, per-frame
  HMAC tags).  ``tcp_over_unix_p50`` isolates what the transport adds
  on the hot path; the daemon serves both listeners from one pool.

Writes ``BENCH_schedd.json`` next to this file.

Usage: PYTHONPATH=src python -m benchmarks.bench_schedd
Env:   POLYTOPS_BENCH_REPS=N warm-latency repeat count (default 30)
"""
from __future__ import annotations

import json
import os
import statistics
import subprocess
import sys
import tempfile
import threading
import time
from pathlib import Path

from repro.core import akg
from repro.core import schedcache
from repro.core.schedclient import SchedClient, local_only
from repro.core.scop import Scop
from repro.core.wire import KEY_ENV

HERE = Path(__file__).resolve().parent
OUT = HERE / "BENCH_schedd.json"

N_CLIENTS = 4
PLAN_SHAPE = (96, 96, 96)
TCP_KEY = b"bench-schedd-shared-key"


def _bench_scop() -> Scop:
    s = Scop("bench_schedd", params={"N": 48})
    with s.loop("i", 0, "N"):
        with s.loop("j", 0, "N"):
            s.stmt("A[i,j] = A[i,j] + 1")
    return s


def start_daemon(sock: str, pool: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(HERE.parent / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.pop("POLYTOPS_SCHEDD_SOCK", None)
    env[KEY_ENV] = TCP_KEY.decode()
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.schedd", "--sock", sock,
         "--cache-dir", pool, "--chaos", "--listen", "127.0.0.1:0",
         "--port-file", sock + ".port"],
        env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    client = SchedClient(sock, retries=0)
    deadline = time.monotonic() + 20.0
    while time.monotonic() < deadline:
        try:
            client.ping(timeout=1.0)
            return proc
        except Exception:
            if proc.poll() is not None:
                raise RuntimeError(f"daemon exited rc={proc.returncode}")
            time.sleep(0.05)
    proc.kill()
    raise RuntimeError("daemon never answered ping within 20s")


def stop_daemon(proc, sock: str) -> None:
    try:
        SchedClient(sock, retries=0).shutdown(timeout=2.0)
    except Exception:
        pass
    try:
        proc.wait(timeout=5.0)
    except subprocess.TimeoutExpired:
        proc.kill()
        proc.wait(timeout=5.0)


def bench_coalescing(sock: str) -> dict:
    scop = _bench_scop()
    stats0 = SchedClient(sock, retries=0).daemon_stats()
    results, errors = [], []

    def one_client():
        try:
            c = SchedClient(sock, retries=0, request_timeout=60.0)
            # raw request: coalescing is a daemon property, keep the
            # client's retry/fallback machinery out of the measurement
            resp = c._request({"op": "schedule", "scop": scop,
                               "test_delay_s": 0.4}, 60.0)
            results.append(resp["meta"])
        except Exception as e:          # noqa: BLE001 — tallied below
            errors.append(f"{type(e).__name__}: {e}")

    threads = [threading.Thread(target=one_client) for _ in range(N_CLIENTS)]
    for t in threads:
        t.start()
        time.sleep(0.05)    # overlap inside the 0.4s compute window
    for t in threads:
        t.join(timeout=90.0)
    stats1 = SchedClient(sock, retries=0).daemon_stats()
    delta = {k: stats1["counters"][k] - stats0["counters"][k]
             for k in ("computed", "coalesced", "frame_hits")}
    return {"clients": N_CLIENTS, "answered": len(results),
            "errors": errors, **delta}


def bench_warm_latency(sock: str, pool: str, reps: int) -> dict:
    m, n, k = PLAN_SHAPE
    client = SchedClient(sock, retries=0, request_timeout=60.0)
    client.remote_plan("matmul", m, n, k, "tensor")      # warm the frame
    daemon_ms = []
    for _ in range(reps):
        t0 = time.perf_counter()
        client.remote_plan("matmul", m, n, k, "tensor")
        daemon_ms.append((time.perf_counter() - t0) * 1e3)

    # in-process disk-hit reference: same pool the daemon warmed, with
    # the plan memo and the cache's memory tier cleared every rep so
    # each call is a genuine pickle-from-disk + lower
    prev = schedcache._GLOBAL
    schedcache._GLOBAL = schedcache.ScheduleCache(cache_dir=pool)
    local_ms = []
    try:
        with local_only():
            akg.plan_matmul.cache_clear()
            akg.plan_matmul(m, n, k)                     # warm the disk pool
            for _ in range(reps):
                akg.plan_matmul.cache_clear()
                schedcache._GLOBAL.mem.clear()
                t0 = time.perf_counter()
                akg.plan_matmul(m, n, k)
                local_ms.append((time.perf_counter() - t0) * 1e3)
        disk_hits = schedcache._GLOBAL.stats.disk_hits
    finally:
        schedcache._GLOBAL = prev

    def pct(xs, q):
        return round(statistics.quantiles(xs, n=100)[q - 1], 4)

    d50, d99 = pct(daemon_ms, 50), pct(daemon_ms, 99)
    l50, l99 = pct(local_ms, 50), pct(local_ms, 99)
    return {"reps": reps, "daemon_p50_ms": d50, "daemon_p99_ms": d99,
            "inprocess_p50_ms": l50, "inprocess_p99_ms": l99,
            "ratio_p50": round(d50 / l50, 3) if l50 else None,
            "inprocess_disk_hits": disk_hits}


def tcp_address(sock: str, proc) -> str:
    port_file = sock + ".port"
    deadline = time.monotonic() + 10.0
    while time.monotonic() < deadline:
        if os.path.exists(port_file):
            return "127.0.0.1:" + Path(port_file).read_text().strip()
        if proc.poll() is not None:
            raise RuntimeError(f"daemon exited rc={proc.returncode}")
        time.sleep(0.05)
    raise RuntimeError("daemon never wrote its port file")


def bench_warm_tcp(addr: str, reps: int, unix_p50: float) -> dict:
    """The same warm frame-cache hit over authenticated localhost TCP:
    one pooled connection (one handshake), per-frame MAC both ways."""
    m, n, k = PLAN_SHAPE
    client = SchedClient(addr, retries=0, request_timeout=60.0,
                         key=TCP_KEY)
    client.remote_plan("matmul", m, n, k, "tensor")      # frame is warm
    tcp_ms = []
    for _ in range(reps):
        t0 = time.perf_counter()
        client.remote_plan("matmul", m, n, k, "tensor")
        tcp_ms.append((time.perf_counter() - t0) * 1e3)
    stats = client.stats.as_dict()
    client.close()

    def pct(xs, q):
        return round(statistics.quantiles(xs, n=100)[q - 1], 4)

    t50, t99 = pct(tcp_ms, 50), pct(tcp_ms, 99)
    return {"reps": reps, "tcp_p50_ms": t50, "tcp_p99_ms": t99,
            "tcp_over_unix_p50": (round(t50 / unix_p50, 3)
                                  if unix_p50 else None),
            "dials": stats["dials"], "reuses": stats["reuses"]}


def bench_fallback() -> dict:
    c = SchedClient("/nonexistent/schedd.sock", retries=0,
                    connect_timeout=0.2)
    with tempfile.TemporaryDirectory() as tmp:
        prev = schedcache._GLOBAL
        schedcache._GLOBAL = schedcache.ScheduleCache(cache_dir=tmp)
        try:
            for _ in range(3):
                plan = c.plan("matmul", 64, 64, 64)
                assert plan is not None
        finally:
            schedcache._GLOBAL = prev
    return {"requests": 3, **c.stats.as_dict()}


def main() -> int:
    reps = int(os.environ.get("POLYTOPS_BENCH_REPS", "30"))
    tmp = tempfile.mkdtemp(prefix="bench_schedd_")
    sock = os.path.join(tmp, "schedd.sock")
    pool = os.path.join(tmp, "pool")
    proc = start_daemon(sock, pool)
    try:
        coalescing = bench_coalescing(sock)
        warm = bench_warm_latency(sock, pool, reps)
        warm_tcp = bench_warm_tcp(tcp_address(sock, proc), reps,
                                  warm["daemon_p50_ms"])
        final = SchedClient(sock, retries=0).daemon_stats()
    finally:
        stop_daemon(proc, sock)
    fallback = bench_fallback()

    counters = final["counters"]
    served = counters["requests"]
    hits = counters["frame_hits"] + counters["coalesced"]
    out = {
        "coalescing": coalescing,
        "warm_latency": warm,
        "warm_latency_tcp": warm_tcp,
        "fallback": fallback,
        "fallbacks": fallback["fallbacks"],
        "daemon_counters": counters,
        "daemon_cache": final["cache"],
        "frame_hit_rate": round(hits / served, 3) if served else None,
        "journal_recovered": final["journal_recovered"],
    }
    OUT.write_text(json.dumps(out, indent=2, sort_keys=True) + "\n")
    print(f"coalescing: {coalescing['clients']} clients -> "
          f"{coalescing['computed']} computed, "
          f"{coalescing['coalesced']} coalesced, "
          f"{coalescing['frame_hits']} frame hits "
          f"({len(coalescing['errors'])} errors)")
    print(f"warm plan latency: daemon p50 {warm['daemon_p50_ms']}ms "
          f"p99 {warm['daemon_p99_ms']}ms | in-process disk-hit p50 "
          f"{warm['inprocess_p50_ms']}ms p99 {warm['inprocess_p99_ms']}ms "
          f"| ratio p50 {warm['ratio_p50']}x")
    print(f"warm plan over TCP: p50 {warm_tcp['tcp_p50_ms']}ms "
          f"p99 {warm_tcp['tcp_p99_ms']}ms "
          f"({warm_tcp['tcp_over_unix_p50']}x unix, "
          f"{warm_tcp['dials']} dial / {warm_tcp['reuses']} reuses)")
    print(f"fallback (no daemon): {fallback['fallbacks']}/"
          f"{fallback['requests']} served in-process")
    print(f"wrote {OUT}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
