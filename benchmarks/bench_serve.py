"""Serving-engine bench: continuous batching + Pallas fast path vs the
alternating prefill/decode baseline, on the smoke config (CPU).

Both engines run the SAME greedy workload (B prompts, fixed token
budget) with warmed jits, and the gate requires **bit-identical
generated tokens** — the continuous engine's chunked prefill, paged KV,
fused decode dispatches, and Pallas kernels must not change a single
logit argmax.  Reported per engine:

* ``tokens_per_s``           — median-of-REPS wall-clock throughput
* ``p50/p99_inter_token_ms`` — from a ``sync=True`` continuous run
  (per-tick host sync so each token has a timestamp; throughput numbers
  come from the async run, latency from the sync run)
* ``overlap_ratio``          — fraction of busy engine ticks that ran a
  prefill chunk and a decode dispatch together

Gated metrics (host-portable, see scripts/bench_compare.py):
``speedup_tokens_per_s`` (continuous/baseline, same host same run),
``tokens_identical``, ``p99_over_p50_inter_token``, and
``paged_memory_ratio`` — the roofline memory-term ratio of the
baseline's full-cache decode step vs the paged decode step, derived
from compiled HLO ``cost_analysis()`` through
:mod:`repro.launch.roofline` (structural: counts bytes the compiled
step touches, not wall clock).

Writes ``BENCH_serve.json`` next to this file.

Usage: PYTHONPATH=src python -m benchmarks.bench_serve
Env:   POLYTOPS_SERVE_BATCH    slots            (default 4)
       POLYTOPS_SERVE_PLEN     prompt length    (default 32)
       POLYTOPS_SERVE_GEN      tokens/request   (default 32)
       POLYTOPS_SERVE_MAXLEN   cache rows       (default 256)
       POLYTOPS_SERVE_CHUNK    prefill chunk    (default 16)
       POLYTOPS_SERVE_REPS     timed reps       (default 5)
"""
from __future__ import annotations

import json
import os
import statistics
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.registry import ShapeConfig, get_arch
from repro.launch.roofline import (collective_bytes_from_hlo,
                                   roofline_terms)
from repro.launch.serve import ContinuousEngine, Request, ServeEngine
from repro.model import pallas_mode
from repro.model import transformer as T

HERE = Path(__file__).resolve().parent
OUT = HERE / "BENCH_serve.json"

ARCH = os.environ.get("POLYTOPS_SERVE_ARCH", "granite_3_2b")
B = int(os.environ.get("POLYTOPS_SERVE_BATCH", "4"))
PLEN = int(os.environ.get("POLYTOPS_SERVE_PLEN", "32"))
GEN = int(os.environ.get("POLYTOPS_SERVE_GEN", "32"))
MAXLEN = int(os.environ.get("POLYTOPS_SERVE_MAXLEN", "256"))
CHUNK = int(os.environ.get("POLYTOPS_SERVE_CHUNK", "16"))
REPS = int(os.environ.get("POLYTOPS_SERVE_REPS", "5"))


def _prompts(cfg, key):
    return [jax.random.randint(jax.random.fold_in(key, i), (1, PLEN), 2,
                               cfg.vocab) for i in range(B)]


def _run_baseline(eng, prompts):
    reqs = [Request(i, p) for i, p in enumerate(prompts)]
    for i, r in enumerate(reqs):
        eng.admit(r, slot=i)
    for _ in range(GEN - 1):
        eng.step()
    return reqs


def _run_continuous(eng, prompts):
    reqs = [Request(i, p) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return reqs


def _timed(run, eng, prompts):
    times = []
    for _ in range(REPS):
        eng.reset()
        t0 = time.time()
        reqs = run(eng, prompts)
        times.append(time.time() - t0)
    ntok = sum(len(r.generated) for r in reqs)
    med = statistics.median(times)
    return {"tokens": ntok, "wall_s_median": round(med, 5),
            "wall_s_best": round(min(times), 5),
            "tokens_per_s": round(ntok / med, 1)}, reqs


def _latency(eng, prompts):
    eng.reset()
    reqs = _run_continuous(eng, prompts)
    gaps = []
    for r in reqs:
        ts = r.token_times
        gaps.extend((b - a) * 1e3 for a, b in zip(ts, ts[1:]))
    gaps.sort()
    if not gaps:
        return {"p50_ms": 0.0, "p99_ms": 0.0}, reqs
    p50 = gaps[len(gaps) // 2]
    p99 = gaps[min(int(len(gaps) * 0.99), len(gaps) - 1)]
    return {"p50_ms": round(p50, 3), "p99_ms": round(p99, 3),
            "gaps": len(gaps)}, reqs


def _decode_roofline(cfg, lengths):
    """Roofline terms for one compiled decode dispatch: the baseline's
    full-cache ``decode_step`` vs the paged ``serve_decode_step``."""
    params = jax.eval_shape(lambda k: T.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    cache = jax.eval_shape(lambda: T.init_cache(cfg, B, MAXLEN))
    toks = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    shape = ShapeConfig("serve_decode", MAXLEN, B, "decode")

    def stats(fn, *args, **kw):
        compiled = jax.jit(fn, **kw).lower(*args).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        coll = collective_bytes_from_hlo(compiled.as_text())
        return roofline_terms(cfg, shape, cost, coll, 1)

    full = stats(lambda p, t, c: T.decode_step(p, cfg, t, c, MAXLEN - 1),
                 params, toks, cache)
    lens = jax.ShapeDtypeStruct((B,), jnp.int32)
    act = jax.ShapeDtypeStruct((B,), jnp.bool_)
    kv = lengths  # page-aligned bucket actually used mid-run
    paged = stats(lambda p, t, c, l, a:
                  T.serve_decode_step(p, cfg, t, c, l, a, kv),
                  params, toks, cache, lens, act)
    return {"full": full, "paged": paged, "paged_kv_rows": kv,
            "full_kv_rows": MAXLEN}


def run(out=sys.stdout):
    cfg = get_arch(ARCH).smoke()
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    prompts = _prompts(cfg, key)

    base = ServeEngine(cfg, params, B, MAXLEN)
    base_reqs = _run_baseline(base, prompts)          # warm compile
    base_tokens = [r.generated for r in base_reqs]
    base_stats, _ = _timed(_run_baseline, base, prompts)

    cont = ContinuousEngine(cfg, params, B, MAXLEN, chunk=CHUNK,
                            use_pallas=True, max_new=GEN)
    cont_reqs = _run_continuous(cont, prompts)        # warm compile
    cont_tokens = [r.generated for r in cont_reqs]
    cont_stats, last = _timed(_run_continuous, cont, prompts)
    overlap = cont.overlap_ratio()
    identical = (base_tokens == cont_tokens
                 and cont_tokens == [r.generated for r in last])

    sync_eng = ContinuousEngine(cfg, params, B, MAXLEN, chunk=CHUNK,
                                use_pallas=True, max_new=GEN, sync=True)
    _run_continuous(sync_eng, prompts)                # warm compile
    lat, sync_reqs = _latency(sync_eng, prompts)
    identical = identical and cont_tokens == [r.generated
                                              for r in sync_reqs]
    pallas_mode.configure(enabled=False)

    roof = _decode_roofline(cfg, cont._bucket(PLEN + GEN))
    mem_ratio = roof["full"]["memory_s"] / max(roof["paged"]["memory_s"],
                                               1e-30)
    speedup = base_stats["wall_s_median"] / max(
        cont_stats["wall_s_median"], 1e-9)

    doc = {
        "arch": ARCH, "batch": B, "prompt_len": PLEN, "gen": GEN,
        "max_len": MAXLEN, "chunk": CHUNK, "reps": REPS,
        "page": cont.page,
        "baseline": base_stats,
        "continuous": cont_stats,
        "speedup_tokens_per_s": round(speedup, 3),
        "tokens_identical": int(identical),
        "overlap_ratio": round(overlap, 3),
        "inter_token": lat,
        "p99_over_p50_inter_token": round(
            lat["p99_ms"] / max(lat["p50_ms"], 1e-9), 3),
        "paged_memory_ratio": round(mem_ratio, 3),
        "roofline_decode": roof,
    }
    OUT.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    print(f"serve bench: baseline {base_stats['tokens_per_s']} tok/s, "
          f"continuous {cont_stats['tokens_per_s']} tok/s "
          f"({speedup:.2f}x), identical={bool(identical)}, "
          f"overlap={overlap:.2f}, page={cont.page}, "
          f"p99/p50 inter-token={doc['p99_over_p50_inter_token']}, "
          f"paged memory ratio={mem_ratio:.2f}", file=out)
    print(f"wrote {OUT}", file=out)
    return doc


def main(argv=None) -> int:
    doc = run()
    ok = (doc["tokens_identical"] == 1
          and doc["speedup_tokens_per_s"] >= 1.3)
    if not ok:
        print("bench_serve: FAIL — "
              f"identical={doc['tokens_identical']} "
              f"speedup={doc['speedup_tokens_per_s']} (need >=1.3x)",
              file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
