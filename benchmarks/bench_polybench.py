"""Paper Fig. 2 reproduction: PolyBench, 4 strategies + kernel-specific,
speedups vs the pluto-style baseline (our Pluto reproduction).

The kernel-specific configuration comes from the real autotuner
(:mod:`repro.core.autotune`): cache-model tile sizing + bounded
strategy/tile/wavefront search, statically ranked, top-k measured, the
winner persisted in the schedule cache (repeat runs of this benchmark
reuse the tuned configs without re-searching).

Output CSV: kernel,variant,us_per_call,speedup_vs_pluto
Alongside the CSV, a machine-readable ``BENCH_polybench.json`` is
written next to this file (per-kernel us/call, speedups, fallback
flags, checksum status, the tuned config and the kernel-specific
geomean) — the perf-trajectory artifact future PRs regress against,
like ``BENCH_scheduler.json``.
"""
from __future__ import annotations

import json
import math
import sys
from pathlib import Path
from typing import Dict, List

from repro.core.autotune import autotune
from repro.core.deps import compute_dependences
from repro.core.scops_polybench import REGISTRY

from .common import FAST, NO_CACHE, SCALARS, Measurement, check_checksums, measure, standard_variants, tuned_variant

FAST_SET = ["gemm", "mvt", "jacobi1d", "jacobi2d", "trmm", "gesummv"]

# §III-E axis demonstrators, measured alongside the fast set: kernels
# where a non-default fusion or cost-mix choice wins outright (atax:
# maximal fusion of the A·x / Aᵀ·y products; covariance: the 'pc'
# proximity-first cost mix).  Kept OUT of the fast-set geomean so the
# PR-over-PR regression basket stays comparable; their rows, tuned
# configs and axis usage are reported like every other kernel.
AXIS_SET = ["atax", "covariance"]

# kernels whose schedule needs negative coefficients: both Pluto and
# PolyTOPS fall back to the original schedule (paper §IV-B) — we include
# one as a fallback demonstration and skip the rest for time.
FALLBACK_DEMO: List[str] = []


def run(out=sys.stdout) -> Dict[str, Dict[str, Measurement]]:
    kernels = FAST_SET + AXIS_SET if FAST else list(REGISTRY)
    results: Dict[str, Dict[str, Measurement]] = {}
    report: Dict[str, dict] = {}
    n_errors = 0
    n_mismatch = 0
    n_autotune_failures = 0
    print("kernel,variant,us_per_call,speedup_vs_pluto", file=out)
    for name in kernels:
        entry = {"variants": {}, "errors": [], "checksum_ok": True}
        report[name] = entry
        try:
            scop = REGISTRY[name]()
            deps = compute_dependences(scop)
            ms: List[Measurement] = []
            variants = list(standard_variants())
            tuned = None
            try:
                tuned = autotune(scop, scalars=SCALARS,
                                 use_cache=not NO_CACHE)
                variants.append(tuned_variant(tuned.config))
            except Exception as e:
                # tracked separately from CSV ERROR rows: the kernel
                # still measures, only the tuned config is missing
                entry["autotune_error"] = type(e).__name__
                n_autotune_failures += 1
            for v in variants:
                try:
                    ms.append(measure(scop, v, deps=deps))
                except Exception as e:  # schedule/compile failure is a result
                    print(f"{name},{v.name},ERROR,{type(e).__name__}", file=out)
                    entry["errors"].append(f"{v.name}:{type(e).__name__}")
            if not ms:
                n_errors += len(entry["errors"])
                continue
            entry["checksum_ok"] = check_checksums(name, ms)
            if not entry["checksum_ok"]:
                n_mismatch += 1
            base = next((m.seconds for m in ms if m.variant == "pluto-style"), None)
            res = {m.variant: m for m in ms}
            # kernel-specific = the autotuned configuration's measurement
            ks = None
            if tuned is not None and tuned.config.label in res:
                ks = res[tuned.config.label]
            if ks is None:      # autotuner unavailable: best measured
                ks = min(ms, key=lambda m: m.seconds)
            res["kernel-specific"] = Measurement(
                f"kernel-specific({ks.variant})", ks.seconds, ks.checksum,
                ks.sched_seconds, ks.fallback)
            for m in list(res.values()):
                sp = base / m.seconds if base else float("nan")
                print(f"{name},{m.variant},{m.seconds*1e6:.1f},{sp:.3f}", file=out)
                if hasattr(out, "flush"):
                    out.flush()
                entry["variants"][m.variant] = {
                    "us_per_call": round(m.seconds * 1e6, 1),
                    "speedup_vs_pluto": round(sp, 3) if base else None,
                    "fallback": bool(m.fallback),
                }
            if tuned is not None:
                entry["tuned"] = {
                    "config": tuned.config.label,
                    "source": tuned.source,      # 'measured' | 'cache'
                    "ranker": tuned.ranker,      # 'analytic' | 'learned'
                    # winner exercises the fusion / cost-mix axes?
                    "uses_new_axes": bool(tuned.config.uses_new_axes),
                    "static_rank": tuned.ranked[:5],
                }
            results[name] = res
            n_errors += len(entry["errors"])
        except Exception as e:
            print(f"{name},KERNEL_FAILED,{type(e).__name__}:{e}", file=out)
            entry["errors"].append(f"KERNEL_FAILED:{type(e).__name__}")
            # count every error of this kernel, including per-variant ones
            # recorded before the kernel-level failure
            n_errors += len(entry["errors"])
    # geomean of kernel-specific speedups (paper: 1.7–1.8x).  In FAST
    # mode only the historical regression basket (FAST_SET) enters the
    # geomean — the AXIS_SET demonstrators are reported but not
    # averaged, so the number stays comparable across PRs.
    basket = set(FAST_SET) if FAST else set(results)
    sps = []
    for name, res in results.items():
        if name not in basket:
            continue
        base = res.get("pluto-style")
        ks = res.get("kernel-specific")
        if base and ks:
            sps.append(base.seconds / ks.seconds)
    g = math.exp(sum(math.log(s) for s in sps) / len(sps)) if sps else None
    if g is not None:
        print(f"GEOMEAN,kernel-specific_vs_pluto,{g:.3f},n={len(sps)}", file=out)
    summary = {
        "kernels": report,
        "geomean_kernel_specific_vs_pluto": round(g, 3) if g else None,
        "n_kernels": len(sps),           # geomean basket size
        "n_measured_kernels": len(report),
        "total_errors": n_errors,
        "checksum_mismatches": n_mismatch,
        "autotune_failures": n_autotune_failures,
        # kernels whose winning config uses a non-default fusion or
        # cost-mix choice — the proof the §III-E axes matter
        "non_default_axis_winners": sorted(
            k for k, e in report.items()
            if e.get("tuned", {}).get("uses_new_axes")),
        "fast": FAST,
        "fast_set": FAST_SET,
        "axis_set": AXIS_SET,
    }
    out_path = Path(__file__).parent / "BENCH_polybench.json"
    out_path.write_text(json.dumps(summary, indent=2, sort_keys=True))
    print(f"# kernel-specific geomean {g and round(g, 3)}x over {len(sps)} "
          f"kernels; errors={n_errors} mismatches={n_mismatch} -> {out_path}",
          file=out)
    return results


if __name__ == "__main__":
    run()
