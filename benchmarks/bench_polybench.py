"""Paper Fig. 2 reproduction: PolyBench, 4 strategies + kernel-specific,
speedups vs the pluto-style baseline (our Pluto reproduction).

Output CSV: kernel,variant,us_per_call,speedup_vs_pluto
"""
from __future__ import annotations

import sys
from typing import Dict, List

from repro.core.deps import compute_dependences
from repro.core.scops_polybench import REGISTRY, SIZE

from .common import (FAST, Measurement, Variant, check_checksums,
                     kernel_specific_variants, measure, standard_variants)

FAST_SET = ["gemm", "mvt", "jacobi1d", "jacobi2d", "trmm", "gesummv"]

# kernels whose schedule needs negative coefficients: both Pluto and
# PolyTOPS fall back to the original schedule (paper §IV-B) — we include
# one as a fallback demonstration and skip the rest for time.
FALLBACK_DEMO: List[str] = []


def run(out=sys.stdout) -> Dict[str, Dict[str, Measurement]]:
    kernels = FAST_SET if FAST else list(REGISTRY)
    results: Dict[str, Dict[str, Measurement]] = {}
    print("kernel,variant,us_per_call,speedup_vs_pluto", file=out)
    for name in kernels:
        try:
            scop = REGISTRY[name]()
            deps = compute_dependences(scop)
            ms: List[Measurement] = []
            for v in standard_variants() + kernel_specific_variants():
                try:
                    ms.append(measure(scop, v, deps=deps))
                except Exception as e:  # schedule/compile failure is a result too
                    print(f"{name},{v.name},ERROR,{type(e).__name__}", file=out)
            if not ms:
                continue
            check_checksums(name, ms)
            base = next((m.seconds for m in ms if m.variant == "pluto-style"), None)
            res = {m.variant: m for m in ms}
            # kernel-specific = best measured configuration
            best = min(ms, key=lambda m: m.seconds)
            res["kernel-specific"] = Measurement(
                f"kernel-specific({best.variant})", best.seconds, best.checksum,
                best.sched_seconds, best.fallback)
            for m in list(res.values()):
                sp = base / m.seconds if base else float("nan")
                print(f"{name},{m.variant},{m.seconds*1e6:.1f},{sp:.3f}", file=out)
                if hasattr(out, "flush"):
                    out.flush()
            results[name] = res
        except Exception as e:
            print(f"{name},KERNEL_FAILED,{type(e).__name__}:{e}", file=out)
    # geomean of kernel-specific speedups (paper: 1.7–1.8x)
    import math
    sps = []
    for name, res in results.items():
        base = res.get("pluto-style")
        ks = res.get("kernel-specific")
        if base and ks:
            sps.append(base.seconds / ks.seconds)
    if sps:
        g = math.exp(sum(math.log(s) for s in sps) / len(sps))
        print(f"GEOMEAN,kernel-specific_vs_pluto,{g:.3f},n={len(sps)}", file=out)
    return results


if __name__ == "__main__":
    run()
