"""Paper Fig. 3 reproduction: jacobi-1d across dataset sizes.

Two configurations — the large-size dedicated one (tensor-style fusion:
simple, fully sequential, vector-friendly) and pluto-style (skewed,
enables parallelism) — measured at multiple (T, N) sizes.

Output CSV: size,variant,us_per_call,speedup_vs_pluto
"""
from __future__ import annotations

import sys

from repro.core import config as CFG
from repro.core.deps import compute_dependences
from repro.core.scops_polybench import make_jacobi1d

from .common import FAST, Variant, check_checksums, measure

SIZES = [(20, 30), (50, 120), (100, 400), (200, 1000), (500, 4000),
         (500, 16000), (1000, 64000)]


def run(out=sys.stdout):
    sizes = SIZES[:4] if FAST else SIZES
    print("size,variant,us_per_call,speedup_vs_pluto", file=out)
    for t, n in sizes:
        scop = make_jacobi1d((t, n))
        deps = compute_dependences(scop)
        variants = [
            Variant("pluto-style", CFG.pluto_style),
            Variant("dedicated(tensor)", CFG.tensor_style),
            Variant("pluto+tile32+wave", CFG.pluto_style, tile=32, wavefront=True),
        ]
        ms = [measure(scop, v, deps=deps) for v in variants]
        check_checksums(f"jacobi1d:{t}x{n}", ms)
        base = next(m.seconds for m in ms if m.variant == "pluto-style")
        for m in ms:
            print(f"T{t}_N{n},{m.variant},{m.seconds*1e6:.1f},"
                  f"{base/m.seconds:.3f}", file=out)


if __name__ == "__main__":
    run()
