"""Scheduler-cost benchmark: wall time of PolyTOPS itself per kernel.

Compares, per PolyBench/NPU kernel and strategy:

* ``seed``        — the seed pipeline (monolithic ILP, clone-per-lexmin
                    dense solves, no caching; ``incremental=False``)
* ``incremental`` — compiled/incremental ILP core, monolithic
* ``decomposed``  — incremental + per-SCC/component ILP decomposition
                    (the default scheduler configuration)
* ``warm``        — repeat scheduling through the structural schedule
                    cache (``repro.core.schedcache``)

Each timing is best-of-``POLYTOPS_BENCH_REPS`` (default 3) of
``PolyTOPSScheduler.schedule()`` only; dependence analysis is timed
separately once per kernel.  All modes run the default exact
lexicographic simplex backend (``engine='lex'``); per-mode exact-pivot
counts are reported alongside the times.  Emits CSV rows to stdout and
writes ``BENCH_scheduler.json`` next to this file with per-kernel
milliseconds, totals, the geomean speedup of the default configuration
over the seed path, and — when ``BENCH_scheduler_pr2_baseline.json``
(the frozen HiGHS-era numbers) is present — the geomean ratio of the
exact backend's decomposed times to that baseline, which tier1.sh gates
at 1.25x.

Usage: PYTHONPATH=src python -m benchmarks.bench_scheduler
Env:   POLYTOPS_BENCH_FAST=1 for a 4-kernel subset,
       POLYTOPS_BENCH_REPS=N for the repeat count,
       POLYTOPS_BENCH_ENGINE to override the solver backend.
"""
from __future__ import annotations

import json
import math
import os
import sys
import time
from pathlib import Path

from repro.core import config as CFG
from repro.core.deps import compute_dependences
from repro.core.schedcache import ScheduleCache, cached_schedule_scop
from repro.core.scheduler import PolyTOPSScheduler
from repro.core.scops_npu import make_lu16, make_trsml, make_trsmu
from repro.core.scops_polybench import REGISTRY

KERNELS = ["gemm", "mm2", "atax", "symm", "lu", "covariance",
           "jacobi2d", "heat3d", "fdtd2d", "durbin", "mm3", "cholesky",
           "gramschmidt", "trisolv", "seidel2d"]
NPU_KERNELS = {"npu_trsml": make_trsml, "npu_trsmu": make_trsmu,
               "npu_lu16": make_lu16}
STRATEGIES = [("pluto-style", CFG.pluto_style),
              ("tensor-style", CFG.tensor_style)]

MODES = {
    "seed": dict(incremental=False),
    "incremental": dict(incremental=True, decompose=False),
    "decomposed": dict(incremental=True, decompose=True),
}


def _time_schedule(scop, cfg, deps, reps: int, engine: str, **kw):
    best = float("inf")
    stats = {}
    for _ in range(reps):
        for d in deps:
            d.satisfied_at = None
        sch = PolyTOPSScheduler(scop, cfg, deps=deps, engine=engine, **kw)
        t0 = time.perf_counter()
        sched = sch.schedule()
        best = min(best, time.perf_counter() - t0)
        stats = sched.stats
    return best, stats


def run(out=sys.stdout):
    fast = os.environ.get("POLYTOPS_BENCH_FAST") == "1"
    reps = max(1, int(os.environ.get("POLYTOPS_BENCH_REPS", "3")))
    engine = os.environ.get("POLYTOPS_BENCH_ENGINE", "lex")
    makers = {k: REGISTRY[k] for k in (KERNELS[:4] if fast else KERNELS)}
    if not fast:
        makers.update(NPU_KERNELS)

    # warm scipy/HiGHS once so the first kernel isn't charged for imports
    from scipy.optimize import linprog  # noqa: F401

    print("kernel,strategy,mode,sched_ms,ilp_solves,pivots,deps", file=out)
    results = {}
    for name, maker in makers.items():
        scop = maker()
        t0 = time.perf_counter()
        deps = compute_dependences(scop)
        dep_ms = (time.perf_counter() - t0) * 1e3
        entry = {"deps_ms": round(dep_ms, 2), "n_deps": len(deps),
                 "strategies": {}}
        for sname, mk in STRATEGIES:
            per = {}
            for mode, kw in MODES.items():
                secs, stats = _time_schedule(scop, mk(), deps, reps, engine,
                                             **kw)
                per[mode] = round(secs * 1e3, 2)
                per[f"{mode}_pivots"] = stats.get("lex_pivots", 0)
                print(f"{name},{sname},{mode},{secs*1e3:.1f},"
                      f"{stats.get('ilp_solves', 0)},"
                      f"{stats.get('lex_pivots', 0)},{len(deps)}", file=out)
            # warm path: repeat scheduling is a structural-cache lookup
            cache = ScheduleCache(disk=False)
            cached_schedule_scop(scop, mk(), cache=cache)
            t0 = time.perf_counter()
            cached_schedule_scop(scop, mk(), cache=cache)
            warm = time.perf_counter() - t0
            per["warm"] = round(warm * 1e3, 4)
            print(f"{name},{sname},warm,{warm*1e3:.3f},0,0,{len(deps)}",
                  file=out)
            per["speedup"] = round(per["seed"] / per["decomposed"], 2)
            entry["strategies"][sname] = per
        results[name] = entry

    speedups = [e["strategies"][s]["speedup"]
                for e in results.values() for s in e["strategies"]]
    totals = {
        mode: round(sum(e["strategies"][s][mode]
                        for e in results.values() for s in e["strategies"]), 1)
        for mode in ("seed", "incremental", "decomposed", "warm")
    }
    geomean = round(math.exp(sum(math.log(s) for s in speedups)
                             / len(speedups)), 2)
    summary = {
        "kernels": results,
        "total_ms": totals,
        "geomean_speedup_decomposed_vs_seed": geomean,
        "engine": engine,
        "reps": reps,
        "fast": fast,
    }
    # regression ratio vs the frozen PR-2 (HiGHS-era) decomposed times:
    # geomean over every kernel×strategy present in both runs
    base_path = Path(__file__).parent / "BENCH_scheduler_pr2_baseline.json"
    if base_path.exists():
        base = json.loads(base_path.read_text())
        ratios = []
        for name, e in results.items():
            bk = base.get("kernels", {}).get(name, {}).get("strategies", {})
            for s, per in e["strategies"].items():
                old = bk.get(s, {}).get("decomposed")
                if old:
                    ratios.append(per["decomposed"] / old)
        if ratios:
            summary["geomean_vs_pr2_baseline"] = round(
                math.exp(sum(math.log(r) for r in ratios) / len(ratios)), 3)
    out_path = Path(__file__).parent / (
        "BENCH_scheduler_fast.json" if fast else "BENCH_scheduler.json")
    out_path.write_text(json.dumps(summary, indent=2, sort_keys=True))
    print(f"# geomean speedup (decomposed vs seed): {geomean}x; "
          f"vs PR2 baseline: {summary.get('geomean_vs_pr2_baseline')}; "
          f"totals {totals} -> {out_path}", file=out)
    return summary


if __name__ == "__main__":
    run()
