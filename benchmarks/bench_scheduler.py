"""Scheduler-cost benchmark: wall time of PolyTOPS itself per kernel and
strategy (dependence analysis + ILP solving), plus ILP solve counts.

Output CSV: kernel,strategy,sched_ms,ilp_solves,deps
"""
from __future__ import annotations

import sys
import time

from repro.core import config as CFG
from repro.core.deps import compute_dependences
from repro.core.scheduler import PolyTOPSScheduler
from repro.core.scops_polybench import REGISTRY

KERNELS = ["gemm", "mm2", "atax", "symm", "lu", "covariance",
           "jacobi2d", "heat3d", "fdtd2d", "durbin"]


def run(out=sys.stdout):
    print("kernel,strategy,sched_ms,ilp_solves,deps", file=out)
    fast = __import__("os").environ.get("POLYTOPS_BENCH_FAST") == "1"
    for name in (KERNELS[:4] if fast else KERNELS):
        scop = REGISTRY[name]()
        t0 = time.time()
        deps = compute_dependences(scop)
        dep_ms = (time.time() - t0) * 1e3
        print(f"{name},dependence-analysis,{dep_ms:.1f},0,{len(deps)}", file=out)
        for cfg in (CFG.pluto_style(), CFG.tensor_style(), CFG.isl_style()):
            sch = PolyTOPSScheduler(scop, cfg, deps=[d for d in deps])
            t0 = time.time()
            sch.schedule()
            ms = (time.time() - t0) * 1e3
            print(f"{name},{cfg.name},{ms:.1f},{sch.stats['ilp_solves']},"
                  f"{len(deps)}", file=out)


if __name__ == "__main__":
    run()
