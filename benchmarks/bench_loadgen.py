"""schedd multi-process load generator: throughput vs worker count.

Launches a real daemon subprocess per ``--workers`` level on a private
socket/pool and drives it with **M separate client processes**, the
shape of a compile farm hitting one shared scheduling daemon.  Two
request mixes per level:

* **distinct** — every request carries a structurally distinct key
  (the scop's param value varies, which feeds ``scop_fingerprint``), so
  nothing coalesces and nothing is warm: every request is a real keyed
  computation.  This is the mix the worker pool exists for — with one
  worker the computations serialize behind the GIL-bound daemon, with N
  workers up to N run concurrently.
* **shared** — every client hammers the SAME key, pinning that the
  pool did not break coalescing: the daemon must compute ONCE and serve
  everyone else from the flight/frame cache.

**Reading the numbers.**  Each computation carries a deterministic
compute hold (the chaos-only ``test_delay_s`` field) and the reported
throughput is requests/second over the mix's wall clock.  The hold
makes the gated ratio a measurement of *dispatch concurrency* — how
many computations the daemon genuinely keeps in flight at once — which
is the property the pool adds and the one that is stable on the 1-2
core CI runners this repo gates on (real solver work would serialize on
the physical cores and measure the machine, not the daemon).  The
tier-1 gate reads ``speedup_distinct_4v1`` (>= 3x: four workers keep at
least 3 distinct-key computations in flight) and
``p99_over_p50_at_max_workers`` (<= 2x: latency stays flat when the
pool is wide enough for the offered load, i.e. no request starves).

Writes ``BENCH_loadgen.json`` next to this file.

**TCP compare** (``--tcp``): one daemon at the max worker level serving
the SAME pool over both transports (``--sock`` + ``--listen``), driven
with the distinct mix over Unix and then over authenticated TCP with
fresh keys.  The gated ratio ``tcp_over_unix_distinct`` isolates the
transport cost (handshake amortized by the client's connection pool,
per-frame HMAC tags) against an identical compute profile; the shared
mix over TCP re-pins coalescing through the authenticated path.
Writes ``BENCH_loadgen_tcp.json``.

Usage: PYTHONPATH=src python -m benchmarks.bench_loadgen [--tcp]
Env:   POLYTOPS_LOADGEN_CLIENTS   client processes        (default 4)
       POLYTOPS_LOADGEN_REQS      requests per client     (default 6)
       POLYTOPS_LOADGEN_HOLD      compute hold seconds    (default 0.15)
       POLYTOPS_LOADGEN_WORKERS   worker sweep, csv       (default 1,2,4)
"""
from __future__ import annotations

import json
import multiprocessing
import os
import statistics
import subprocess
import sys
import tempfile
import time
from pathlib import Path

from repro.core.schedclient import SchedClient
from repro.core.scop import Scop
from repro.core.wire import KEY_ENV

HERE = Path(__file__).resolve().parent
OUT = HERE / "BENCH_loadgen.json"
TCP_OUT = HERE / "BENCH_loadgen_tcp.json"
TCP_KEY = "loadgen-bench-shared-key"


def loadgen_scop(n: int) -> Scop:
    """One structural family; the param value distinguishes cache keys
    at identical compute cost."""
    s = Scop("loadgen", params={"N": n})
    with s.loop("i", 0, "N"):
        with s.loop("j", 0, "N"):
            s.stmt("A[i,j] = A[i,j] + B[j,i]")
    return s


def start_daemon(sock: str, pool: str, workers: int, listen: bool = False):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(HERE.parent / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.pop("POLYTOPS_SCHEDD_SOCK", None)
    args = [sys.executable, "-m", "repro.launch.schedd", "--sock", sock,
            "--cache-dir", pool, "--workers", str(workers),
            "--max-inflight", "64", "--chaos"]
    if listen:
        env[KEY_ENV] = TCP_KEY
        args += ["--listen", "127.0.0.1:0", "--port-file", sock + ".port"]
    proc = subprocess.Popen(
        args, env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    client = SchedClient(sock, retries=0)
    deadline = time.monotonic() + 30.0
    while time.monotonic() < deadline:
        try:
            client.ping(timeout=1.0)
            return proc
        except Exception:
            if proc.poll() is not None:
                raise RuntimeError(f"daemon exited rc={proc.returncode}")
            time.sleep(0.05)
    proc.kill()
    raise RuntimeError("daemon never answered ping within 30s")


def tcp_address(sock: str, proc) -> str:
    """The listening address of a ``listen=True`` daemon (the port file
    is written just after the sockets come up — poll briefly)."""
    port_file = sock + ".port"
    deadline = time.monotonic() + 10.0
    while time.monotonic() < deadline:
        if os.path.exists(port_file):
            return "127.0.0.1:" + Path(port_file).read_text().strip()
        if proc.poll() is not None:
            raise RuntimeError(f"daemon exited rc={proc.returncode}")
        time.sleep(0.05)
    raise RuntimeError("daemon never wrote its port file")


def stop_daemon(proc, sock: str) -> None:
    try:
        SchedClient(sock, retries=0).shutdown(timeout=2.0)
    except Exception:
        pass
    try:
        proc.wait(timeout=10.0)
    except subprocess.TimeoutExpired:
        proc.kill()
        proc.wait(timeout=5.0)


def _client_proc(sock: str, out_path: str, barrier, keys, hold_s: float):
    """One load-generator client process: wait at the barrier so every
    client fires into the same window, then send its requests
    back-to-back, recording per-request wall latency."""
    c = SchedClient(sock, retries=0, request_timeout=300.0)
    lat_ms, errors = [], 0
    barrier.wait(timeout=60.0)
    for n in keys:
        t0 = time.perf_counter()
        try:
            resp = c._request(
                {"op": "schedule", "scop": loadgen_scop(n),
                 "test_delay_s": hold_s}, 300.0)
            if not resp.get("ok"):
                errors += 1
        except Exception:
            errors += 1
        lat_ms.append((time.perf_counter() - t0) * 1e3)
    Path(out_path).write_text(json.dumps(
        {"lat_ms": lat_ms, "errors": errors}))


def run_mix(sock: str, tmp: str, mix: str, clients: int, reqs: int,
            hold_s: float, key_base: int) -> dict:
    """Drive one mix with ``clients`` processes; returns throughput and
    latency percentiles plus the daemon-side counter deltas."""
    before = SchedClient(sock, retries=0).daemon_stats()["counters"]
    ctx = multiprocessing.get_context("fork")
    barrier = ctx.Barrier(clients + 1)
    procs, outs = [], []
    for ci in range(clients):
        if mix == "distinct":
            keys = [key_base + ci * reqs + j for j in range(reqs)]
        else:                             # shared: everyone, same key
            keys = [key_base] * reqs
        out = os.path.join(tmp, f"{mix}_{ci}.json")
        outs.append(out)
        p = ctx.Process(target=_client_proc,
                        args=(sock, out, barrier, keys, hold_s))
        p.start()
        procs.append(p)
    barrier.wait(timeout=60.0)            # release every client at once
    t0 = time.perf_counter()
    for p in procs:
        p.join(timeout=600.0)
    wall_s = time.perf_counter() - t0
    lat_ms, errors = [], 0
    for out in outs:
        row = json.loads(Path(out).read_text())
        lat_ms.extend(row["lat_ms"])
        errors += row["errors"]
    after = SchedClient(sock, retries=0).daemon_stats()["counters"]
    delta = {k: after[k] - before[k]
             for k in ("computed", "coalesced", "frame_hits", "shed",
                       "worker_crashes")}
    total = clients * reqs
    lat_sorted = sorted(lat_ms)
    p50 = statistics.median(lat_sorted) if lat_sorted else None
    p99 = (lat_sorted[max(0, int(len(lat_sorted) * 0.99) - 1)]
           if lat_sorted else None)
    return {
        "requests": total,
        "errors": errors,
        "wall_s": round(wall_s, 4),
        "throughput_rps": round(total / wall_s, 3) if wall_s else None,
        "p50_ms": round(p50, 3) if p50 is not None else None,
        "p99_ms": round(p99, 3) if p99 is not None else None,
        **delta,
    }


def tcp_compare() -> int:
    """Unix vs authenticated-TCP distinct-key throughput on one daemon
    at the max worker level; writes ``BENCH_loadgen_tcp.json``."""
    clients = int(os.environ.get("POLYTOPS_LOADGEN_CLIENTS", "4"))
    reqs = int(os.environ.get("POLYTOPS_LOADGEN_REQS", "6"))
    hold_s = float(os.environ.get("POLYTOPS_LOADGEN_HOLD", "0.15"))
    workers = max(int(w) for w in os.environ.get(
        "POLYTOPS_LOADGEN_WORKERS", "1,2,4").split(","))

    tmp = tempfile.mkdtemp(prefix="loadgen_tcp_")
    sock = os.path.join(tmp, "s.sock")
    pool = os.path.join(tmp, "pool")
    os.environ[KEY_ENV] = TCP_KEY        # forked clients inherit the key
    proc = start_daemon(sock, pool, workers, listen=True)
    try:
        addr = tcp_address(sock, proc)
        key_base = 100
        warm = run_mix(sock, tmp, "distinct", clients, 1, 0.02, key_base)
        key_base += clients
        unix_distinct = run_mix(sock, tmp, "distinct", clients, reqs,
                                hold_s, key_base)
        key_base += clients * reqs
        tcp_distinct = run_mix(addr, tmp, "distinct", clients, reqs,
                               hold_s, key_base)
        key_base += clients * reqs
        tcp_shared = run_mix(addr, tmp, "shared", clients, reqs,
                             hold_s, key_base)
    finally:
        stop_daemon(proc, sock)

    t_unix = unix_distinct["throughput_rps"]
    t_tcp = tcp_distinct["throughput_rps"]
    out = {
        "clients": clients,
        "requests_per_client": reqs,
        "hold_s": hold_s,
        "workers": workers,
        "unix_distinct": unix_distinct,
        "tcp_distinct": tcp_distinct,
        "tcp_shared": tcp_shared,
        "warmup_errors": warm["errors"],
        "tcp_over_unix_distinct": (round(t_tcp / t_unix, 3)
                                   if t_unix and t_tcp else None),
        "errors_total": (unix_distinct["errors"] + tcp_distinct["errors"]
                         + tcp_shared["errors"]),
        "shared_computed_tcp": tcp_shared["computed"],
    }
    TCP_OUT.write_text(json.dumps(out, indent=2, sort_keys=True) + "\n")
    print(f"workers {workers}: unix distinct {t_unix} rps | tcp distinct "
          f"{t_tcp} rps (ratio {out['tcp_over_unix_distinct']}) | tcp "
          f"shared {tcp_shared['computed']} computed, "
          f"{out['errors_total']} errors")
    print(f"wrote {TCP_OUT}")
    return 0


def main() -> int:
    if "--tcp" in sys.argv[1:]:
        return tcp_compare()
    clients = int(os.environ.get("POLYTOPS_LOADGEN_CLIENTS", "4"))
    reqs = int(os.environ.get("POLYTOPS_LOADGEN_REQS", "6"))
    hold_s = float(os.environ.get("POLYTOPS_LOADGEN_HOLD", "0.15"))
    sweep = [int(w) for w in os.environ.get(
        "POLYTOPS_LOADGEN_WORKERS", "1,2,4").split(",")]

    results: dict = {}
    key_base = 100
    for workers in sweep:
        tmp = tempfile.mkdtemp(prefix=f"loadgen_w{workers}_")
        sock = os.path.join(tmp, "s.sock")
        pool = os.path.join(tmp, "pool")
        proc = start_daemon(sock, pool, workers)
        try:
            # warmup: first job per worker pays one-time lazy init;
            # throughput measures steady state
            warm = run_mix(sock, tmp, "distinct", clients,
                           1, 0.02, key_base)
            key_base += clients
            distinct = run_mix(sock, tmp, "distinct", clients, reqs,
                               hold_s, key_base)
            key_base += clients * reqs
            shared = run_mix(sock, tmp, "shared", clients, reqs,
                             hold_s, key_base)
            key_base += 1
            pool_stats = SchedClient(sock, retries=0).daemon_stats()["pool"]
        finally:
            stop_daemon(proc, sock)
        results[str(workers)] = {"distinct": distinct, "shared": shared,
                                 "warmup_errors": warm["errors"],
                                 "pool": pool_stats}
        print(f"workers {workers}: distinct "
              f"{distinct['throughput_rps']} rps "
              f"(p50 {distinct['p50_ms']}ms p99 {distinct['p99_ms']}ms, "
              f"{distinct['errors']} errors) | shared "
              f"{shared['throughput_rps']} rps, "
              f"{shared['computed']} computed", flush=True)

    lo, hi = str(min(sweep)), str(max(sweep))
    t_lo = results[lo]["distinct"]["throughput_rps"]
    t_hi = results[hi]["distinct"]["throughput_rps"]
    p50 = results[hi]["distinct"]["p50_ms"]
    p99 = results[hi]["distinct"]["p99_ms"]
    out = {
        "clients": clients,
        "requests_per_client": reqs,
        "hold_s": hold_s,
        "workers_sweep": sweep,
        "sweep": results,
        "speedup_distinct_4v1": (round(t_hi / t_lo, 3)
                                 if t_lo and t_hi else None),
        "p99_over_p50_at_max_workers": (round(p99 / p50, 3)
                                        if p50 and p99 else None),
        "errors_total": sum(
            r[m]["errors"] for r in results.values()
            for m in ("distinct", "shared")),
        "shared_computed_at_max_workers":
            results[hi]["shared"]["computed"],
    }
    OUT.write_text(json.dumps(out, indent=2, sort_keys=True) + "\n")
    print(f"distinct-key speedup {hi}w vs {lo}w: "
          f"{out['speedup_distinct_4v1']}x | p99/p50 at {hi}w: "
          f"{out['p99_over_p50_at_max_workers']}")
    print(f"wrote {OUT}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
