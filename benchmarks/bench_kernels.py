"""Pallas kernel microbenchmarks (interpret mode on CPU — structural
validation; real perf is the roofline analysis). Filled by kernels/."""
from __future__ import annotations
import sys


def run(out=sys.stdout):
    try:
        from repro.kernels import bench as kb
    except ImportError:
        print("kernels,not_built_yet,0,skip", file=out)
        return
    kb.run(out)


if __name__ == "__main__":
    run()
