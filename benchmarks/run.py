"""Benchmark driver — one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows per section.

  table1     — NPU custom operators, isl vs PolyTOPS directives (Table I)
  fig2       — PolyBench, 4 strategies + autotuned kernel-specific vs
               Pluto (Fig 2); writes BENCH_polybench.json (perf
               trajectory, gated by scripts/tier1.sh like
               BENCH_scheduler.json)
  fig3       — jacobi-1d dataset-size sweep (Fig 3)
  fig4       — scheduling-tool comparison (Fig 4 / Table II, reproduced
               strategies — external tools unavailable offline)
  scheduler  — PolyTOPS compile-time cost
  kernels    — Pallas kernel microbenchmarks (framework layer)
  roofline   — dry-run-derived roofline terms (framework layer; reads
               launch/dryrun results if present)

Usage: PYTHONPATH=src python -m benchmarks.run [section ...]
Env:   POLYTOPS_BENCH_FAST=1 for a quick subset.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    sections = sys.argv[1:] or ["table1", "fig2", "fig3", "fig4",
                                "scheduler", "kernels", "roofline"]
    for s in sections:
        t0 = time.time()
        print(f"\n===== {s} =====")
        try:
            if s == "table1":
                from . import bench_custom_ops as m
            elif s == "fig2":
                from . import bench_polybench as m
            elif s == "fig3":
                from . import bench_datasize as m
            elif s == "fig4":
                from . import bench_sota as m
            elif s == "scheduler":
                from . import bench_scheduler as m
            elif s == "kernels":
                from . import bench_kernels as m
            elif s == "roofline":
                from . import bench_roofline as m
            else:
                print(f"unknown section {s}")
                continue
            m.run()
        except Exception:
            import traceback
            traceback.print_exc()
            print(f"SECTION_FAILED,{s}")
        print(f"===== {s} done in {time.time()-t0:.1f}s =====")


if __name__ == "__main__":
    main()
