"""Shared benchmark machinery: variant → schedule → C source → time.

Results are cached two ways: compiled-run results by source hash
(crunner) and generated C source by a semantic key, so re-running a
benchmark suite is cheap. Set POLYTOPS_NO_CACHE=1 to disable.
"""
from __future__ import annotations

import hashlib
import json
import os
import sys
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, List, Optional, Sequence, Tuple

from repro.core import config as CFG
from repro.core.cbackend import CCodeGenerator
from repro.core.postproc import tile_schedule
from repro.core.scheduler import PolyTOPSScheduler, Schedule
from repro.core.scop import Scop

SALT = "v8"  # bump to invalidate the source cache after codegen changes
SRC_CACHE = Path(os.environ.get("POLYTOPS_SRC_CACHE", "/tmp/polytops_src_cache"))
NO_CACHE = os.environ.get("POLYTOPS_NO_CACHE") == "1"
FAST = os.environ.get("POLYTOPS_BENCH_FAST") == "1"

SCALARS = {"alpha": 1.5, "beta": 0.7, "zero": 0.0, "one": 1.0,
           "fn": 500.0, "eps": 0.1}


@dataclass
class Variant:
    name: str
    config: Callable[[], CFG.SchedulerConfig]
    tile: Optional[object] = None    # int | 'l1' | 'l2' (cache-model sizes)
    wavefront: bool = False
    autovec: bool = False
    original: bool = False     # untransformed program order


def tuned_variant(tc) -> "Variant":
    """Variant for an autotuned kernel-specific config
    (:class:`repro.core.autotune.TunedConfig`).  The config factory is
    the TunedConfig's own ``scheduler_config`` so the fusion mode,
    explicit statement groups and per-dim cost mixes of the winning
    configuration are honored when the benchmark rebuilds the schedule —
    the label (which encodes every axis) keys the source cache."""
    if tc.strategy == "original":    # all-candidates-rejected fallback
        return Variant("original", CFG.SchedulerConfig, original=True)
    return Variant(tc.label, tc.scheduler_config, tile=tc.tile,
                   wavefront=tc.wavefront, autovec=tc.autovec)


def original_schedule(scop: Scop) -> Schedule:
    sch = PolyTOPSScheduler(scop, CFG.SchedulerConfig())
    return sch._fallback_original()


@dataclass
class Measurement:
    variant: str
    seconds: float
    checksum: float
    sched_seconds: float
    fallback: bool

    def row(self, kernel: str) -> str:
        return (f"{kernel},{self.variant},{self.seconds * 1e6:.1f},"
                f"sched_s={self.sched_seconds:.2f},fallback={int(self.fallback)}")


def _source_for(scop: Scop, variant: Variant, deps=None) -> Tuple[str, float, bool]:
    # cache-model tiles ('l1'/'l2') depend on the active CacheSpec: key it,
    # or spec overrides (POLYTOPS_L1_BYTES/POLYTOPS_L2_BYTES) would serve
    # stale sources built with the old sizes
    spec_key = None
    if isinstance(variant.tile, str):
        from repro.core.cachemodel import default_spec
        s = default_spec()
        spec_key = [s.l1_bytes, s.l2_bytes, s.elem_bytes]
    key = hashlib.sha256(
        json.dumps([SALT, scop.name, sorted(scop.params.items()), variant.name,
                    variant.tile, variant.wavefront, variant.autovec,
                    variant.original, spec_key]).encode()
    ).hexdigest()[:24]
    SRC_CACHE.mkdir(parents=True, exist_ok=True)
    cfile = SRC_CACHE / f"{key}.json"
    if not NO_CACHE and cfile.exists():
        data = json.loads(cfile.read_text())
        return data["src"], data["sched_s"], data["fallback"]
    t0 = time.time()
    if variant.original:
        sched = original_schedule(scop)
    else:
        cfg = variant.config()
        if variant.autovec:
            cfg.auto_vectorize = True
        sched = PolyTOPSScheduler(scop, cfg,
                                  deps=[d for d in deps] if deps else None).schedule()
    scan = (tile_schedule(sched, variant.tile, wavefront=variant.wavefront)
            if variant.tile else None)
    scalars = {k: v for k, v in SCALARS.items() if k in scop.scalars}
    src = CCodeGenerator(sched, scan=scan, scalars=scalars).generate()
    sched_s = time.time() - t0
    cfile.write_text(json.dumps({"src": src, "sched_s": sched_s,
                                 "fallback": sched.fallback}))
    return src, sched_s, sched.fallback


def measure(scop: Scop, variant: Variant, deps=None, target_s: float = 0.15,
            timeout: int = 900) -> Measurement:
    from repro.core.crunner import measure_source

    src, sched_s, fb = _source_for(scop, variant, deps)
    r = measure_source(src, tag=f"{scop.name}_{variant.name}",
                       target_s=target_s, timeout=timeout,
                       use_cache=not NO_CACHE)
    return Measurement(variant.name, r.seconds, r.checksum, sched_s, fb)


def check_checksums(kernel: str, ms: Sequence[Measurement], rel: float = 1e-6) -> bool:
    from repro.core.crunner import checksums_match

    vals = [m.checksum for m in ms]
    base = vals[0]
    ok = all(checksums_match(v, base, rel) for v in vals)
    if not ok:
        print(f"WARNING: checksum mismatch for {kernel}: "
              + ", ".join(f"{m.variant}={m.checksum:.9e}" for m in ms), file=sys.stderr)
    return ok


def standard_variants() -> List[Variant]:
    return [
        Variant("original", CFG.SchedulerConfig, original=True),
        Variant("pluto-style", CFG.pluto_style),
        Variant("tensor-style", CFG.tensor_style),
        Variant("isl-style", CFG.isl_style),
        Variant("feautrier-style", CFG.feautrier_style),
    ]


def kernel_specific_variants() -> List[Variant]:
    """The 'playing with cost functions, fusion, vectorization and tiling'
    search space for kernel-specific configurations (paper §IV-B)."""
    return [
        Variant("tensor+autovec", CFG.tensor_style, autovec=True),
        Variant("pluto+tile32", CFG.pluto_style, tile=32),
        Variant("tensor+tile32", CFG.tensor_style, tile=32),
        Variant("pluto+tile32+wave", CFG.pluto_style, tile=32, wavefront=True),
        Variant("bigloops", CFG.bigloops_style),
    ]
