"""Paper Table I reproduction: NPU hybrid custom operators.

isl-style baseline vs PolyTOPS with vectorize directives (and the
auto-vectorization config the paper notes works systematically).
Measured on the CPU C backend (SIMD strip ≙ NPU vector unit); the
speedup *structure* (interchange + innermost vectorization found by
directives, missed by isl-style) reproduces the paper's mechanism.

Output CSV: case,shape,variant,us_per_call,speedup_vs_isl
"""
from __future__ import annotations

import sys
from typing import List

from repro.core.deps import compute_dependences
from repro.core.scops_npu import (TABLE1_SIZES, autovec_config,
                                  baseline_config, directive_config,
                                  make_lu16, make_trsml, make_trsmu)

from .common import FAST, Measurement, Variant, check_checksums, measure


def run(out=sys.stdout):
    print("case,shape,variant,us_per_call,speedup_vs_isl", file=out)
    cases = []
    sizes = dict(TABLE1_SIZES)
    if FAST:
        sizes = {k: v[:2] for k, v in sizes.items()}
    for shape in sizes["trsml"]:
        cases.append((f"trsmL_off_diag", "x".join(map(str, shape)), make_trsml(*shape)))
    for shape in sizes["trsmu"]:
        cases.append((f"trsmU_transpose", "x".join(map(str, shape)), make_trsmu(*shape)))
    cases.append(("LU_decomp", "16x16", make_lu16(16)))

    import math
    speedups = []
    for cname, shape, scop in cases:
        deps = compute_dependences(scop)
        variants = [
            Variant("isl-style", baseline_config),
            Variant("polytops-directives", directive_config),
            Variant("polytops-autovec", autovec_config),
        ]
        ms: List[Measurement] = []
        for v in variants:
            ms.append(measure(scop, v, deps=deps))
        check_checksums(f"{cname}:{shape}", ms)
        base = next(m.seconds for m in ms if m.variant == "isl-style")
        for m in ms:
            sp = base / m.seconds
            print(f"{cname},{shape},{m.variant},{m.seconds*1e6:.2f},{sp:.2f}", file=out)
            if m.variant == "polytops-directives":
                speedups.append(sp)
    g = math.exp(sum(math.log(max(s, 1e-9)) for s in speedups) / len(speedups))
    print(f"GEOMEAN,all,polytops-directives_vs_isl,{g:.2f}", file=out)


if __name__ == "__main__":
    run()
