"""Bench-regression gate: fresh BENCH_*.json vs committed baselines.

Compares the metrics below against ``benchmarks/baselines/`` with
per-metric tolerances and fails (exit 1) on regression.  Only
**host-portable** metrics are gated — ratios of same-run/same-machine
measurements (speedups, latency ratios) and structural counts
(computations, errors) — never absolute milliseconds, which would gate
the CI runner's clock speed instead of the code.

Direction semantics:

* ``higher`` — regression when ``fresh < baseline * (1 - tol)``
* ``lower``  — regression when ``fresh > baseline * (1 + tol)``
  (with a zero baseline, any positive fresh value regresses)

A fresh file that was not produced in this run skips its rows (the CI
matrix runs different bench gates in different jobs and each job
compares whatever it produced); a metric missing a baseline passes with
a note — commit a new baseline to start gating it.  If *nothing* fresh
matched, the gate fails: a comparison over zero metrics is not a gate.

Writes a markdown delta table (for the CI artifact) and prints it.

Usage: python scripts/bench_compare.py \
           [--fresh-dir benchmarks] \
           [--baseline-dir benchmarks/baselines] \
           [--out artifacts/bench_delta.md]

Refreshing baselines intentionally (after a real improvement or an
accepted trade-off):  copy the fresh file over the baseline, e.g.
``cp benchmarks/BENCH_loadgen.json benchmarks/baselines/``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, List, Optional, Tuple

#: (file, dotted metric path, direction, relative tolerance)
SPEC: List[Tuple[str, str, str, float]] = [
    # scheduler smoke: decomposed pipeline vs the seed path, same run
    ("BENCH_scheduler_fast.json",
     "geomean_speedup_decomposed_vs_seed", "higher", 0.20),
    # daemon bench: coalescing is structural (N identical concurrent
    # requests -> exactly 1 computation), warm-hit ratio is same-host
    ("BENCH_schedd.json", "coalescing.computed", "lower", 0.0),
    ("BENCH_schedd.json", "warm_latency.ratio_p50", "lower", 0.75),
    ("BENCH_schedd.json", "frame_hit_rate", "higher", 0.25),
    # load generator: dispatch-concurrency speedup and tail flatness
    ("BENCH_loadgen.json", "speedup_distinct_4v1", "higher", 0.25),
    ("BENCH_loadgen.json", "p99_over_p50_at_max_workers", "lower", 0.50),
    ("BENCH_loadgen.json", "errors_total", "lower", 0.0),
    ("BENCH_loadgen.json", "shared_computed_at_max_workers", "lower", 0.0),
    # TCP transport: authenticated localhost TCP vs Unix, same daemon,
    # same run — the ratio isolates handshake/MAC cost from host speed
    ("BENCH_loadgen_tcp.json", "tcp_over_unix_distinct", "higher", 0.15),
    ("BENCH_loadgen_tcp.json", "errors_total", "lower", 0.0),
    ("BENCH_loadgen_tcp.json", "shared_computed_tcp", "lower", 0.0),
    # serving engine: continuous-batching Pallas path vs the alternating
    # jnp loop, both timed in the same run — the speedup ratio and the
    # greedy-token identity bit are host-portable; paged_memory_ratio is
    # a structural byte count (full KV bytes / paged KV bytes)
    ("BENCH_serve.json", "speedup_tokens_per_s", "higher", 0.30),
    ("BENCH_serve.json", "tokens_identical", "higher", 0.0),
    ("BENCH_serve.json", "paged_memory_ratio", "higher", 0.05),
]


def dig(obj: Any, path: str) -> Optional[float]:
    for part in path.split("."):
        if not isinstance(obj, dict) or part not in obj:
            return None
        obj = obj[part]
    return float(obj) if isinstance(obj, (int, float)) else None


def load(path: str) -> Optional[dict]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def compare(fresh_dir: str, baseline_dir: str):
    rows = []          # (file, metric, baseline, fresh, delta_pct, status)
    regressions = []
    compared = 0
    fresh_cache: dict = {}
    base_cache: dict = {}
    for fname, path, direction, tol in SPEC:
        if fname not in fresh_cache:
            fresh_cache[fname] = load(os.path.join(fresh_dir, fname))
        if fname not in base_cache:
            base_cache[fname] = load(os.path.join(baseline_dir, fname))
        fresh_doc, base_doc = fresh_cache[fname], base_cache[fname]
        if fresh_doc is None:
            rows.append((fname, path, None, None, None,
                         "skipped — not produced in this run"))
            continue
        fresh = dig(fresh_doc, path)
        base = dig(base_doc, path) if base_doc is not None else None
        if fresh is None:
            regressions.append(f"{fname}:{path} missing from fresh run")
            rows.append((fname, path, base, None, None,
                         "FAIL — metric missing"))
            continue
        if base is None:
            rows.append((fname, path, None, fresh, None,
                         "no baseline — commit one to gate"))
            continue
        compared += 1
        if direction == "higher":
            bound = base * (1.0 - tol)
            bad = fresh < bound
        else:
            bound = base * (1.0 + tol)
            bad = fresh > bound
        delta_pct = (round((fresh - base) / base * 100.0, 1)
                     if base else None)
        if bad:
            arrow = "<" if direction == "higher" else ">"
            regressions.append(
                f"{fname}:{path} = {fresh:g} {arrow} allowed {bound:g} "
                f"(baseline {base:g}, tol {tol:.0%}, {direction} is better)")
            status = f"FAIL — past {bound:g}"
        else:
            status = "ok"
        rows.append((fname, path, base, fresh, delta_pct, status))
    return rows, regressions, compared


def markdown(rows) -> str:
    out = ["# Bench delta vs committed baselines", "",
           "| file | metric | baseline | fresh | delta | status |",
           "|---|---|---:|---:|---:|---|"]
    for fname, path, base, fresh, delta, status in rows:
        out.append("| {} | `{}` | {} | {} | {} | {} |".format(
            fname, path,
            "—" if base is None else f"{base:g}",
            "—" if fresh is None else f"{fresh:g}",
            "—" if delta is None else f"{delta:+.1f}%",
            status))
    out.append("")
    return "\n".join(out)


def main(argv=None) -> int:
    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(here)
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh-dir", default=os.path.join(root, "benchmarks"))
    ap.add_argument("--baseline-dir",
                    default=os.path.join(root, "benchmarks", "baselines"))
    ap.add_argument("--out",
                    default=os.path.join(root, "artifacts",
                                         "bench_delta.md"))
    args = ap.parse_args(argv)

    rows, regressions, compared = compare(args.fresh_dir, args.baseline_dir)
    table = markdown(rows)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(table)
    print(table)
    if compared == 0:
        print("bench_compare: FAIL — no fresh metric matched a baseline "
              "(ran without any bench output?)", file=sys.stderr)
        return 1
    if regressions:
        print(f"bench_compare: {len(regressions)} regression(s):",
              file=sys.stderr)
        for r in regressions:
            print(f"  - {r}", file=sys.stderr)
        return 1
    print(f"bench_compare: OK — {compared} metric(s) within tolerance "
          f"({args.out})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
