#!/usr/bin/env python
"""Golden-schedule corpus: dump / diff canonical schedules.

The exact lexicographic simplex backend makes every schedule a pure
function of (kernel, strategy): bit-identical across the seed pipeline,
the incremental pipeline and repeat runs.  This script freezes that
function — one JSON per kernel×strategy combo under
``artifacts/golden_schedules/`` — and lets CI diff fresh schedules
against the frozen corpus, so *any* change that silently alters a
schedule (a pivot-rule tweak, a projection bug, a cost-stage reorder)
fails loudly instead of shipping a perf mystery.

Beyond the 56 kernel×strategy combos the corpus also freezes the
§III-E configuration axes: pluto-style schedules under the ``max``/
``no`` fusion extremes for the multi-SCC kernels
(``<kernel>__pluto_fmax/fno``), and the *statically-ranked* autotune
winner for the polybench fast set (``<kernel>__autotune`` — the
measurement-free part of the search, so the dump is deterministic and
any drift in the candidate enumeration, ranking, or TunedConfig
serialization format is caught by CI).

Usage:
    python scripts/golden_schedules.py check            # diff, exit 1 on drift
    python scripts/golden_schedules.py update           # regenerate corpus
    python scripts/golden_schedules.py check --update-golden   # same as update

A schedule dump records the full signature: per-statement rows (kind +
exact rational coefficients), band structure, per-dimension parallelism,
the fallback flag, the solver tag the corpus was generated with — and
the serialized **schedule tree** (``repro.core.schedtree``): loop
structure, FM-derived bounds, separation decisions and parallel/vector/
tile marks, so tree *construction* is determinism-gated alongside the
schedule rows (a separation or bound-pruning change fails CI loudly
even when the rows are unchanged).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import config as CFG                       # noqa: E402
from repro.core.ilp import SOLVER_TAG                      # noqa: E402
from repro.core.scheduler import PolyTOPSScheduler         # noqa: E402
from repro.core.scops_npu import (make_lu16, make_trsml,   # noqa: E402
                                  make_trsmu)
from repro.core.scops_polybench import REGISTRY            # noqa: E402

GOLDEN_DIR = Path(__file__).resolve().parent.parent / "artifacts" / "golden_schedules"
STRATEGIES = ("pluto", "tensor")

#: fusion-variant combos (paper §III-E fusion axis): deterministic
#: pluto-style schedules under the max/no fusion extremes, frozen for
#: the multi-SCC kernels where they differ structurally from 'smart'
FUSION_VARIANTS = {"pluto_fmax": ("pluto", "max"), "pluto_fno": ("pluto", "no")}
FUSION_KERNELS = ("fdtd2d", "gemm", "gesummv", "mm2", "mm3", "mvt")

#: kernels whose *statically-ranked* autotune winner is frozen too —
#: measure=False makes the result a pure function of the SCoP and the
#: search space, so any drift in the candidate enumeration, the analytic
#: ranking or the TunedConfig serialization format fails CI loudly
AUTOTUNE_KERNELS = ("gemm", "gesummv", "jacobi1d", "jacobi2d", "mvt", "trmm")


def all_kernels():
    makers = dict(REGISTRY)
    makers.update({"npu_trsml": make_trsml, "npu_trsmu": make_trsmu,
                   "npu_lu16": make_lu16})
    return makers


def schedule_dump(sched) -> dict:
    from repro.core.schedtree import schedule_tree, tree_to_json

    rows = {}
    for idx, rr in sorted(sched.rows.items()):
        rows[str(idx)] = [
            [r.kind, {"|".join(map(str, k)): str(v)
                      for k, v in sorted(r.coeffs.items())}]
            for r in rr
        ]
    try:
        tree = tree_to_json(schedule_tree(sched))
    except ValueError as e:
        # deterministic marker for schedules no backend can scan
        # (non-invertible / unbounded) — still drift-gated
        tree = {"error": str(e)}
    return {
        "solver": SOLVER_TAG,
        "rows": rows,
        "bands": list(sched.bands),
        "parallel": list(sched.parallel),
        "fallback": bool(sched.fallback),
        "tree": tree,
    }


def autotune_dump(scop) -> dict:
    """Deterministic static-autotune record: the winning configuration,
    the ranked candidate labels and the search-space version, computed
    against a fixed CacheSpec (no env overrides) and a throwaway cache
    (no measurement pool → analytic ranking)."""
    from repro.core.autotune import SPACE_VERSION, autotune
    from repro.core.cachemodel import CacheSpec
    from repro.core.schedcache import ScheduleCache

    r = autotune(scop, measure=False, use_cache=False,
                 cache=ScheduleCache(disk=False), spec=CacheSpec())
    dump = {
        "solver": SOLVER_TAG,
        "space_version": SPACE_VERSION,
        "winner": r.to_dict()["config"],
        "label": r.config.label,
        "ranker": r.ranker,
        "ranked": r.ranked[:8],
    }
    # tuples → lists, exactly as a reloaded golden file sees them
    return json.loads(json.dumps(dump))


def compute_all():
    out = {}
    makers = all_kernels()
    for name, mk in sorted(makers.items()):
        for style in STRATEGIES:
            sched = PolyTOPSScheduler(mk(), CFG.STRATEGIES[style]()).schedule()
            out[f"{name}__{style}"] = schedule_dump(sched)
    for name in FUSION_KERNELS:
        for combo, (style, fm) in sorted(FUSION_VARIANTS.items()):
            cfg = CFG.STRATEGIES[style]()
            cfg.fusion_mode = fm
            sched = PolyTOPSScheduler(makers[name](), cfg).schedule()
            out[f"{name}__{combo}"] = schedule_dump(sched)
    for name in AUTOTUNE_KERNELS:
        out[f"{name}__autotune"] = autotune_dump(makers[name]())
    return out


def update() -> int:
    GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
    fresh = compute_all()
    for stale in GOLDEN_DIR.glob("*.json"):
        if stale.stem not in fresh:
            stale.unlink()
    for combo, dump in fresh.items():
        (GOLDEN_DIR / f"{combo}.json").write_text(
            json.dumps(dump, indent=1, sort_keys=True) + "\n")
    print(f"golden corpus updated: {len(fresh)} combos -> {GOLDEN_DIR}")
    return 0


def check() -> int:
    fresh = compute_all()
    missing, drifted, stale = [], [], []
    for combo, dump in fresh.items():
        path = GOLDEN_DIR / f"{combo}.json"
        if not path.exists():
            missing.append(combo)
            continue
        golden = json.loads(path.read_text())
        if golden != dump:
            drifted.append(combo)
    known = {p.stem for p in GOLDEN_DIR.glob("*.json")}
    stale = sorted(known - set(fresh))
    if missing or drifted or stale:
        for combo in missing:
            print(f"GOLDEN MISSING: {combo} (run --update-golden)")
        for combo in drifted:
            print(f"GOLDEN DRIFT:   {combo} — schedule changed; inspect, then "
                  f"--update-golden if intentional")
        for combo in stale:
            print(f"GOLDEN STALE:   {combo} no longer produced")
        return 1
    print(f"golden schedules OK: {len(fresh)} combos bit-identical")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("mode", choices=["check", "update"], nargs="?",
                    default="check")
    ap.add_argument("--update-golden", action="store_true",
                    help="regenerate the corpus instead of checking")
    args = ap.parse_args()
    if args.update_golden or args.mode == "update":
        return update()
    return check()


if __name__ == "__main__":
    sys.exit(main())
