#!/usr/bin/env bash
# Tier-1 gate: the full test suite plus two smoke benchmarks under
# wall-clock budgets, so perf regressions fail loudly alongside
# correctness regressions:
#   * scheduler smoke — compile-time cost (floor: 2.0x geomean vs seed)
#   * polybench smoke — generated-code runtime on the fast set
#     (checksum-gated; ERROR rows fail; floor: 1.3x kernel-specific
#     geomean vs pluto-style)
#
# Usage:  scripts/tier1.sh
# Env:    POLYTOPS_TIER1_BUDGET     scheduler smoke budget in s (default 180)
#         POLYTOPS_TIER1_PB_BUDGET  polybench smoke budget in s (default 900)
set -u
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

BUDGET="${POLYTOPS_TIER1_BUDGET:-180}"
PB_BUDGET="${POLYTOPS_TIER1_PB_BUDGET:-900}"

echo "== tier-1 tests =="
python -m pytest -x -q || exit 1

echo "== scheduler smoke bench (fast subset, ${BUDGET}s budget) =="
BENCH_OUT="$(mktemp)"
if ! POLYTOPS_BENCH_FAST=1 POLYTOPS_BENCH_REPS=2 \
     timeout "$BUDGET" python -m benchmarks.bench_scheduler > "$BENCH_OUT"; then
  echo "SMOKE BENCH FAILED or exceeded ${BUDGET}s budget" >&2
  tail -5 "$BENCH_OUT" >&2
  rm -f "$BENCH_OUT"
  exit 1
fi
tail -1 "$BENCH_OUT"
rm -f "$BENCH_OUT"

# the smoke bench must keep a healthy margin over the seed path
python - <<'PY' || exit 1
import json, pathlib, sys
d = json.loads(pathlib.Path("benchmarks/BENCH_scheduler_fast.json").read_text())
g = d["geomean_speedup_decomposed_vs_seed"]
if g < 2.0:
    sys.exit(f"scheduler speedup regressed: geomean {g}x < 2.0x floor")
print(f"scheduler speedup OK: geomean {g}x (floor 2.0x)")
PY

echo "== polybench smoke bench (fast set, ${PB_BUDGET}s budget) =="
PB_OUT="$(mktemp)"
if ! POLYTOPS_BENCH_FAST=1 \
     timeout "$PB_BUDGET" python -m benchmarks.bench_polybench > "$PB_OUT"; then
  echo "POLYBENCH SMOKE FAILED or exceeded ${PB_BUDGET}s budget" >&2
  tail -5 "$PB_OUT" >&2
  rm -f "$PB_OUT"
  exit 1
fi
tail -1 "$PB_OUT"
rm -f "$PB_OUT"

# generated-code quality gate: no errors, no checksum mismatches, and a
# healthy kernel-specific geomean over the pluto-style baseline
python - <<'PY' || exit 1
import json, pathlib, sys
d = json.loads(pathlib.Path("benchmarks/BENCH_polybench.json").read_text())
errs = d["total_errors"]
mism = d["checksum_mismatches"]
g = d["geomean_kernel_specific_vs_pluto"]
if errs:
    bad = {k: v["errors"] for k, v in d["kernels"].items() if v["errors"]}
    sys.exit(f"polybench smoke has {errs} ERROR rows: {bad}")
if mism:
    sys.exit(f"polybench smoke has {mism} checksum mismatches")
at_fail = d.get("autotune_failures", 0)
if at_fail:
    bad = {k: v.get("autotune_error") for k, v in d["kernels"].items()
           if v.get("autotune_error")}
    sys.exit(f"autotuner failed on {at_fail} kernel(s): {bad}")
if g is None or g < 1.3:
    sys.exit(f"kernel-specific speedup regressed: geomean {g}x < 1.3x floor")
print(f"polybench OK: kernel-specific geomean {g}x over "
      f"{d['n_kernels']} kernels (floor 1.3x), 0 errors, 0 mismatches")
PY
echo "== tier-1 gate passed =="
