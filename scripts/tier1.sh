#!/usr/bin/env bash
# Tier-1 gate: the full test suite plus a scheduler smoke benchmark
# under a wall-clock budget, so scheduler perf regressions fail loudly
# alongside correctness regressions.
#
# Usage:  scripts/tier1.sh
# Env:    POLYTOPS_TIER1_BUDGET  smoke-bench budget in seconds (default 180)
set -u
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

BUDGET="${POLYTOPS_TIER1_BUDGET:-180}"

echo "== tier-1 tests =="
python -m pytest -x -q || exit 1

echo "== scheduler smoke bench (fast subset, ${BUDGET}s budget) =="
BENCH_OUT="$(mktemp)"
if ! POLYTOPS_BENCH_FAST=1 POLYTOPS_BENCH_REPS=2 \
     timeout "$BUDGET" python -m benchmarks.bench_scheduler > "$BENCH_OUT"; then
  echo "SMOKE BENCH FAILED or exceeded ${BUDGET}s budget" >&2
  tail -5 "$BENCH_OUT" >&2
  rm -f "$BENCH_OUT"
  exit 1
fi
tail -1 "$BENCH_OUT"
rm -f "$BENCH_OUT"

# the smoke bench must keep a healthy margin over the seed path
python - <<'PY' || exit 1
import json, pathlib, sys
d = json.loads(pathlib.Path("benchmarks/BENCH_scheduler_fast.json").read_text())
g = d["geomean_speedup_decomposed_vs_seed"]
if g < 2.0:
    sys.exit(f"scheduler speedup regressed: geomean {g}x < 2.0x floor")
print(f"scheduler speedup OK: geomean {g}x (floor 2.0x)")
PY
echo "== tier-1 gate passed =="
