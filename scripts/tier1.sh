#!/usr/bin/env bash
# Tier-1 gate: test suite + determinism + perf smoke, machine-readable.
#
# Gates (all selected gates must pass; any failure exits nonzero):
#   * tests      — the full pytest suite (with line coverage when
#                  pytest-cov is installed)
#   * coverage   — line-coverage floor for src/repro/core (gated from
#                  coverage.xml; skipped-but-ok when pytest-cov is not
#                  installed — CI always installs it).  Requires the
#                  tests gate in the same run (it produces coverage.xml).
#   * golden     — fresh schedules for all 74 combos (56 kernel×strategy
#                  + fusion-variant extremes + static-autotune winners)
#                  diff bit-exact against artifacts/golden_schedules/
#                  (regenerate intentionally via
#                   `python scripts/golden_schedules.py --update-golden`)
#   * sched_bench — scheduler smoke bench under a wall-clock budget:
#                  decomposed-vs-seed geomean floor, and the exact
#                  backend's decomposed times within 1.25x (geomean) of
#                  a same-run, same-machine HiGHS-engine reference (the
#                  PR-2 backend), so the gate measures code, not host
#                  speed; the frozen dev-machine PR-2 numbers in
#                  BENCH_scheduler_pr2_baseline.json are reported as
#                  informational context only
#   * polybench  — generated-code smoke on the fast set (checksum-gated;
#                  ERROR rows fail; kernel-specific geomean floor 1.3x)
#   * pallas     — JAX-CPU (interpret) smoke: every Pallas kernel runs
#                  through the schedule-tree → lower_to_kernel_plan
#                  lowering and must numerically match kernels/ref.py
#   * chaos      — seeded fault-injection sweep (scripts/chaos_sweep.py):
#                  every fault site × the fast-set kernels must yield a
#                  legal schedule (numpy-oracle differential) or a clean
#                  typed error, bit-deterministically — including the
#                  schedd daemon scenarios (kill -9 mid-request and of a
#                  pool worker, garbage frames, slow-loris, version
#                  skew, missing socket); writes artifacts/chaos_summary.json
#   * schedd     — scheduling-daemon load bench (benchmarks/bench_schedd.py):
#                  concurrent identical requests must coalesce to one
#                  computation, and warm-hit plan latency through the
#                  daemon must stay within 2x of the in-process
#                  disk-hit path; writes benchmarks/BENCH_schedd.json
#   * loadgen    — multi-process load generator (benchmarks/bench_loadgen.py):
#                  distinct-key throughput at --workers 4 must be >= 3x
#                  the single-worker daemon with p99 <= 2x p50, zero
#                  request errors, and the shared-key mix must still
#                  coalesce to exactly one computation; writes
#                  benchmarks/BENCH_loadgen.json
#   * loadgen_tcp — loadgen TCP compare (bench_loadgen --tcp): one
#                  daemon at max workers serving the same pool over
#                  Unix and authenticated TCP; distinct-key TCP
#                  throughput must stay within ~10% of Unix, zero
#                  errors, and shared keys must still coalesce to one
#                  computation through the authenticated path; writes
#                  benchmarks/BENCH_loadgen_tcp.json
#   * serve      — serving-engine bench (benchmarks/bench_serve.py):
#                  continuous batching (chunked prefill interleaved with
#                  decode, paged KV, Pallas kernels) vs the alternating
#                  jnp loop on the granite smoke config; greedy tokens
#                  must be bit-identical and tokens/sec >= 1.3x; writes
#                  benchmarks/BENCH_serve.json
#   * bench_compare — regression gate: fresh BENCH_*.json from this run
#                  vs benchmarks/baselines/ with per-metric tolerances
#                  (scripts/bench_compare.py); only host-portable ratio
#                  and count metrics are compared; writes
#                  artifacts/bench_delta.md
#
# Every run writes artifacts/tier1_summary.json (per-gate ok + metrics)
# for CI to upload/consume, even when a gate fails.  The summary's "ok"
# covers exactly the gates selected for that run.
#
# Usage:  scripts/tier1.sh [gate ...]      # no args = every gate
#   e.g.  scripts/tier1.sh tests coverage pallas
#         scripts/tier1.sh chaos schedd loadgen bench_compare
# Env:    POLYTOPS_TIER1_BUDGET       scheduler smoke budget in s (default 240)
#         POLYTOPS_TIER1_PB_BUDGET    polybench smoke budget in s (default 1200)
#         POLYTOPS_TIER1_REQUIRE_COV  1 = fail (not skip) when pytest-cov
#                                     is missing (CI sets this)
set -u
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

ALL_GATES=(tests coverage golden sched_bench polybench pallas chaos schedd
           loadgen loadgen_tcp serve bench_compare)
if [ "$#" -gt 0 ]; then
  GATES=("$@")
  for g in "${GATES[@]}"; do
    case " ${ALL_GATES[*]} " in
      *" $g "*) ;;
      *) echo "unknown gate '$g' (known: ${ALL_GATES[*]})" >&2; exit 2 ;;
    esac
  done
else
  GATES=("${ALL_GATES[@]}")
fi
export TIER1_GATES="${GATES[*]}"

want() {  # want <gate> — is the gate selected for this run?
  case " ${GATES[*]} " in *" $1 "*) return 0 ;; *) return 1 ;; esac
}

if want coverage && ! want tests; then
  echo "the coverage gate reads coverage.xml produced by the tests gate;" >&2
  echo "select both: scripts/tier1.sh tests coverage ..." >&2
  exit 2
fi

BUDGET="${POLYTOPS_TIER1_BUDGET:-240}"
PB_BUDGET="${POLYTOPS_TIER1_PB_BUDGET:-1200}"
RESULTS="$(mktemp)"
mkdir -p artifacts

record() {  # record <gate> <ok 0|1> <detail-json>
  printf '%s\t%s\t%s\n' "$1" "$2" "${3:-{\}}" >> "$RESULTS"
}

finish() {
  python - "$RESULTS" <<'PY' > artifacts/tier1_summary.json
import json, os, sys, pathlib
gates = {}
for ln in pathlib.Path(sys.argv[1]).read_text().splitlines():
    name, ok, detail = ln.split("\t", 2)
    gates[name] = {"ok": ok == "1"}
    try:
        gates[name].update(json.loads(detail))
    except json.JSONDecodeError:
        pass
expected = os.environ["TIER1_GATES"].split()
ok = all(gates.get(g, {}).get("ok") for g in expected)
print(json.dumps({"ok": ok, "selected": expected, "gates": gates},
                 indent=2, sort_keys=True))
PY
  rm -f "$RESULTS"
  echo "== tier-1 summary written to artifacts/tier1_summary.json =="
}
trap finish EXIT

if want tests; then
echo "== tier-1 tests =="
T0=$SECONDS
HAVE_COV=0
COV_ARGS=()
if python -c "import pytest_cov" 2>/dev/null; then
  HAVE_COV=1
  COV_ARGS=(--cov=repro.core --cov-report=xml:coverage.xml --cov-report=)
fi
if python -m pytest -x -q ${COV_ARGS[@]+"${COV_ARGS[@]}"}; then
  record tests 1 "{\"seconds\": $((SECONDS - T0))}"
else
  record tests 0 "{\"seconds\": $((SECONDS - T0))}"
  exit 1
fi
fi

if want coverage; then
echo "== coverage floor for src/repro/core =="
if [ "$HAVE_COV" = 1 ]; then
  if python - <<'PY'
import json, pathlib, sys
import xml.etree.ElementTree as ET
FLOOR = 60.0   # ratchet floor, percent of src/repro/core lines executed
root = ET.parse("coverage.xml").getroot()
pct = round(float(root.attrib["line-rate"]) * 100.0, 2)
detail = {"line_coverage_pct": pct, "floor_pct": FLOOR,
          "scope": "repro.core"}
pathlib.Path(".tier1_cov_detail.json").write_text(json.dumps(detail))
if pct < FLOOR:
    sys.exit(f"core coverage {pct}% < {FLOOR}% floor")
print(f"coverage OK: repro.core {pct}% line coverage (floor {FLOOR}%)")
PY
  then
    record coverage 1 "$(cat .tier1_cov_detail.json)"
    rm -f .tier1_cov_detail.json
  else
    record coverage 0 "$(cat .tier1_cov_detail.json 2>/dev/null || echo '{}')"
    rm -f .tier1_cov_detail.json
    exit 1
  fi
elif [ "${POLYTOPS_TIER1_REQUIRE_COV:-0}" = 1 ]; then
  # a gate that silently records ok when its tool is missing is not a
  # gate — CI requires coverage, so a missing pytest-cov is a failure
  echo "COVERAGE REQUIRED but pytest-cov is not installed" >&2
  record coverage 0 '{"error": "coverage required but pytest-cov not installed"}'
  exit 1
else
  echo "pytest-cov not installed: coverage gate skipped (CI installs it)"
  record coverage 1 '{"skipped": true, "reason": "pytest-cov not installed"}'
fi
fi

if want golden; then
echo "== golden-schedule determinism gate (74 combos) =="
T0=$SECONDS
if python scripts/golden_schedules.py check; then
  record golden 1 "{\"seconds\": $((SECONDS - T0)), \"combos\": 74}"
else
  record golden 0 "{\"seconds\": $((SECONDS - T0))}"
  exit 1
fi
fi

if want sched_bench; then
echo "== scheduler smoke bench (fast subset, ${BUDGET}s budget each engine) =="
BENCH_OUT="$(mktemp)"
# same-machine HiGHS-engine reference first (the PR-2 backend) ...
if ! POLYTOPS_BENCH_FAST=1 POLYTOPS_BENCH_REPS=2 POLYTOPS_BENCH_ENGINE=highs \
     timeout "$BUDGET" python -m benchmarks.bench_scheduler > "$BENCH_OUT"; then
  echo "HIGHS REFERENCE BENCH FAILED or exceeded ${BUDGET}s budget" >&2
  tail -5 "$BENCH_OUT" >&2
  rm -f "$BENCH_OUT"
  record sched_bench 0 '{"error": "highs reference bench failed or over budget"}'
  exit 1
fi
mv benchmarks/BENCH_scheduler_fast.json benchmarks/BENCH_scheduler_fast_highs.json
# ... then the default exact backend
if ! POLYTOPS_BENCH_FAST=1 POLYTOPS_BENCH_REPS=2 \
     timeout "$BUDGET" python -m benchmarks.bench_scheduler > "$BENCH_OUT"; then
  echo "SMOKE BENCH FAILED or exceeded ${BUDGET}s budget" >&2
  tail -5 "$BENCH_OUT" >&2
  rm -f "$BENCH_OUT"
  record sched_bench 0 '{"error": "bench failed or over budget"}'
  exit 1
fi
tail -1 "$BENCH_OUT"
rm -f "$BENCH_OUT"

# the smoke bench must keep a healthy margin over the seed path AND the
# exact backend must stay within 1.25x (geomean) of the same-run HiGHS
# reference — both engines measured on this machine, this commit
if python - <<'PY'
import json, math, pathlib, sys
d = json.loads(pathlib.Path("benchmarks/BENCH_scheduler_fast.json").read_text())
h = json.loads(
    pathlib.Path("benchmarks/BENCH_scheduler_fast_highs.json").read_text())
g = d["geomean_speedup_decomposed_vs_seed"]
ratios = []
for name, e in d["kernels"].items():
    hk = h["kernels"].get(name, {}).get("strategies", {})
    for s, per in e["strategies"].items():
        ref = hk.get(s, {}).get("decomposed")
        if ref:
            ratios.append(per["decomposed"] / ref)
r = (round(math.exp(sum(math.log(x) for x in ratios) / len(ratios)), 3)
     if ratios else None)
bad = []
if g < 2.0:
    bad.append(f"decomposed-vs-seed geomean {g}x < 2.0x floor")
if r is not None and r > 1.25:
    bad.append(f"exact backend {r}x slower than same-run HiGHS (cap 1.25x)")
detail = {"geomean_speedup_decomposed_vs_seed": g,
          "geomean_vs_highs_same_run": r,
          "geomean_vs_pr2_dev_baseline": d.get("geomean_vs_pr2_baseline")}
pathlib.Path(".tier1_sched_detail.json").write_text(json.dumps(detail))
if bad:
    sys.exit("; ".join(bad))
print(f"scheduler bench OK: {g}x over seed (floor 2.0x), "
      f"{r}x vs same-run HiGHS (cap 1.25x)")
PY
then
  record sched_bench 1 "$(cat .tier1_sched_detail.json)"
  rm -f .tier1_sched_detail.json
else
  record sched_bench 0 "$(cat .tier1_sched_detail.json 2>/dev/null || echo '{}')"
  rm -f .tier1_sched_detail.json
  exit 1
fi
fi

if want polybench; then
echo "== polybench smoke bench (fast set, ${PB_BUDGET}s budget) =="
PB_OUT="$(mktemp)"
if ! POLYTOPS_BENCH_FAST=1 \
     timeout "$PB_BUDGET" python -m benchmarks.bench_polybench > "$PB_OUT"; then
  echo "POLYBENCH SMOKE FAILED or exceeded ${PB_BUDGET}s budget" >&2
  tail -5 "$PB_OUT" >&2
  rm -f "$PB_OUT"
  record polybench 0 '{"error": "bench failed or over budget"}'
  exit 1
fi
tail -1 "$PB_OUT"
rm -f "$PB_OUT"

# generated-code quality gate: no errors, no checksum mismatches, and a
# healthy kernel-specific geomean over the pluto-style baseline
if python - <<'PY'
import json, pathlib, sys
d = json.loads(pathlib.Path("benchmarks/BENCH_polybench.json").read_text())
errs = d["total_errors"]
mism = d["checksum_mismatches"]
g = d["geomean_kernel_specific_vs_pluto"]
detail = {"geomean_kernel_specific_vs_pluto": g, "errors": errs,
          "checksum_mismatches": mism, "n_kernels": d["n_kernels"]}
pathlib.Path(".tier1_pb_detail.json").write_text(json.dumps(detail))
if errs:
    bad = {k: v["errors"] for k, v in d["kernels"].items() if v["errors"]}
    sys.exit(f"polybench smoke has {errs} ERROR rows: {bad}")
if mism:
    sys.exit(f"polybench smoke has {mism} checksum mismatches")
at_fail = d.get("autotune_failures", 0)
if at_fail:
    bad = {k: v.get("autotune_error") for k, v in d["kernels"].items()
           if v.get("autotune_error")}
    sys.exit(f"autotuner failed on {at_fail} kernel(s): {bad}")
if g is None or g < 1.3:
    sys.exit(f"kernel-specific speedup regressed: geomean {g}x < 1.3x floor")
print(f"polybench OK: kernel-specific geomean {g}x over "
      f"{d['n_kernels']} kernels (floor 1.3x), 0 errors, 0 mismatches")
PY
then
  record polybench 1 "$(cat .tier1_pb_detail.json)"
  rm -f .tier1_pb_detail.json
else
  record polybench 0 "$(cat .tier1_pb_detail.json 2>/dev/null || echo '{}')"
  rm -f .tier1_pb_detail.json
  exit 1
fi
fi

if want pallas; then
echo "== pallas smoke (JAX CPU, interpret mode, tree lowering) =="
T0=$SECONDS
PALLAS_OUT="$(mktemp)"
if JAX_PLATFORMS=cpu timeout 600 python -m repro.kernels.bench --smoke \
     > "$PALLAS_OUT" 2>&1; then
  cat "$PALLAS_OUT"
  record pallas 1 "{\"seconds\": $((SECONDS - T0))}"
  rm -f "$PALLAS_OUT"
else
  cat "$PALLAS_OUT" >&2
  echo "PALLAS SMOKE FAILED (crash or numerical mismatch vs kernels/ref.py)" >&2
  record pallas 0 "{\"seconds\": $((SECONDS - T0))}"
  rm -f "$PALLAS_OUT"
  exit 1
fi
fi

if want chaos; then
echo "== chaos sweep (fault injection + daemon × fast set, 120s budget) =="
T0=$SECONDS
if timeout 120 python scripts/chaos_sweep.py --out artifacts/chaos_summary.json; then
  CH_DETAIL="$(python - <<'PY'
import json
d = json.load(open("artifacts/chaos_summary.json"))
print(json.dumps({"seconds": d["seconds"], "scenarios": d["n_scenarios"],
                  "failures": d["n_failures"]}))
PY
)"
  record chaos 1 "$CH_DETAIL"
else
  echo "CHAOS SWEEP FAILED (escaped exception, illegal degraded schedule," >&2
  echo "nondeterministic fingerprint, hung daemon, or never-fired armed site)" >&2
  record chaos 0 "{\"seconds\": $((SECONDS - T0))}"
  exit 1
fi
fi

if want schedd; then
echo "== schedd daemon bench (coalescing + warm-hit latency, 120s budget) =="
T0=$SECONDS
if ! timeout 120 python -m benchmarks.bench_schedd; then
  echo "SCHEDD BENCH FAILED or exceeded 120s budget" >&2
  record schedd 0 "{\"seconds\": $((SECONDS - T0))}"
  exit 1
fi
if python - <<'PY'
import json, pathlib, sys
d = json.loads(pathlib.Path("benchmarks/BENCH_schedd.json").read_text())
co = d["coalescing"]
warm = d["warm_latency"]
detail = {"computed": co["computed"], "coalesced": co["coalesced"],
          "clients": co["clients"],
          "daemon_warm_p50_ms": warm["daemon_p50_ms"],
          "inprocess_disk_p50_ms": warm["inprocess_p50_ms"],
          "warm_ratio": warm["ratio_p50"],
          "fallbacks": d["fallbacks"]}
pathlib.Path(".tier1_schedd_detail.json").write_text(json.dumps(detail))
bad = []
if co["computed"] != 1 or co["coalesced"] < 1:
    bad.append(f"{co['clients']} identical concurrent requests -> "
               f"{co['computed']} computations, {co['coalesced']} coalesced "
               f"(want 1 computation, >=1 coalesced)")
if warm["ratio_p50"] > 2.0:
    bad.append(f"warm-hit p50 through daemon {warm['daemon_p50_ms']:.3f}ms is "
               f"{warm['ratio_p50']:.2f}x the in-process disk hit "
               f"{warm['inprocess_p50_ms']:.3f}ms (cap 2.0x)")
if bad:
    sys.exit("; ".join(bad))
print(f"schedd OK: {co['clients']} clients -> {co['computed']} computation "
      f"({co['coalesced']} coalesced); warm p50 {warm['daemon_p50_ms']:.2f}ms "
      f"vs in-process {warm['inprocess_p50_ms']:.2f}ms "
      f"({warm['ratio_p50']:.2f}x, cap 2.0x)")
PY
then
  record schedd 1 "$(cat .tier1_schedd_detail.json)"
  rm -f .tier1_schedd_detail.json
else
  record schedd 0 "$(cat .tier1_schedd_detail.json 2>/dev/null || echo '{}')"
  rm -f .tier1_schedd_detail.json
  exit 1
fi
fi

if want loadgen; then
echo "== schedd load generator (worker-pool scaling, 600s budget) =="
T0=$SECONDS
if ! timeout 600 python -m benchmarks.bench_loadgen; then
  echo "LOADGEN BENCH FAILED or exceeded 600s budget" >&2
  record loadgen 0 "{\"seconds\": $((SECONDS - T0))}"
  exit 1
fi
if python - <<'PY'
import json, pathlib, sys
d = json.loads(pathlib.Path("benchmarks/BENCH_loadgen.json").read_text())
speedup = d["speedup_distinct_4v1"]
tail = d["p99_over_p50_at_max_workers"]
errors = d["errors_total"]
shared = d["shared_computed_at_max_workers"]
detail = {"speedup_distinct_4v1": speedup,
          "p99_over_p50_at_max_workers": tail,
          "errors_total": errors,
          "shared_computed_at_max_workers": shared,
          "workers_sweep": d["workers_sweep"]}
pathlib.Path(".tier1_loadgen_detail.json").write_text(json.dumps(detail))
bad = []
if speedup is None or speedup < 3.0:
    bad.append(f"distinct-key speedup at max workers {speedup}x < 3.0x floor")
if tail is None or tail > 2.0:
    bad.append(f"p99/p50 at max workers {tail}x > 2.0x cap (starvation)")
if errors:
    bad.append(f"{errors} request error(s) under load (want 0)")
if shared != 1:
    bad.append(f"shared-key mix computed {shared} times (pool broke "
               f"coalescing; want exactly 1)")
if bad:
    sys.exit("; ".join(bad))
print(f"loadgen OK: {speedup}x distinct-key speedup (floor 3.0x), "
      f"p99/p50 {tail}x (cap 2.0x), 0 errors, shared mix computed once")
PY
then
  record loadgen 1 "$(cat .tier1_loadgen_detail.json)"
  rm -f .tier1_loadgen_detail.json
else
  record loadgen 0 "$(cat .tier1_loadgen_detail.json 2>/dev/null || echo '{}')"
  rm -f .tier1_loadgen_detail.json
  exit 1
fi
fi

if want loadgen_tcp; then
echo "== schedd loadgen TCP compare (unix vs authenticated tcp, 600s budget) =="
T0=$SECONDS
if ! timeout 600 python -m benchmarks.bench_loadgen --tcp; then
  echo "LOADGEN TCP BENCH FAILED or exceeded 600s budget" >&2
  record loadgen_tcp 0 "{\"seconds\": $((SECONDS - T0))}"
  exit 1
fi
if python - <<'PY'
import json, pathlib, sys
d = json.loads(pathlib.Path("benchmarks/BENCH_loadgen_tcp.json").read_text())
ratio = d["tcp_over_unix_distinct"]
errors = d["errors_total"]
shared = d["shared_computed_tcp"]
detail = {"tcp_over_unix_distinct": ratio, "errors_total": errors,
          "shared_computed_tcp": shared, "workers": d["workers"]}
pathlib.Path(".tier1_loadgen_tcp_detail.json").write_text(json.dumps(detail))
bad = []
if ratio is None or ratio < 0.9:
    bad.append(f"TCP distinct-key throughput is {ratio}x the Unix-socket "
               f"run (floor 0.9x — the transport may not cost >10%)")
if errors:
    bad.append(f"{errors} request error(s) over TCP (want 0)")
if shared != 1:
    bad.append(f"shared-key mix over TCP computed {shared} times "
               f"(auth path broke coalescing; want exactly 1)")
if bad:
    sys.exit("; ".join(bad))
print(f"loadgen_tcp OK: TCP/Unix distinct throughput {ratio}x "
      f"(floor 0.9x), 0 errors, shared mix computed once over TCP")
PY
then
  record loadgen_tcp 1 "$(cat .tier1_loadgen_tcp_detail.json)"
  rm -f .tier1_loadgen_tcp_detail.json
else
  record loadgen_tcp 0 "$(cat .tier1_loadgen_tcp_detail.json 2>/dev/null || echo '{}')"
  rm -f .tier1_loadgen_tcp_detail.json
  exit 1
fi
fi

if want serve; then
echo "== serve bench (continuous batching vs alternating loop, 600s budget) =="
T0=$SECONDS
if ! JAX_PLATFORMS=cpu timeout 600 python -m benchmarks.bench_serve; then
  echo "SERVE BENCH FAILED or exceeded 600s budget" >&2
  record serve 0 "{\"seconds\": $((SECONDS - T0))}"
  exit 1
fi
if python - <<'PY'
import json, pathlib, sys
d = json.loads(pathlib.Path("benchmarks/BENCH_serve.json").read_text())
speedup = d["speedup_tokens_per_s"]
ident = d["tokens_identical"]
detail = {"speedup_tokens_per_s": speedup,
          "tokens_identical": ident,
          "overlap_ratio": d["overlap_ratio"],
          "p99_over_p50_inter_token": d["p99_over_p50_inter_token"],
          "paged_memory_ratio": d["paged_memory_ratio"],
          "tokens_per_s_continuous": d["continuous"]["tokens_per_s"],
          "tokens_per_s_baseline": d["baseline"]["tokens_per_s"]}
pathlib.Path(".tier1_serve_detail.json").write_text(json.dumps(detail))
bad = []
if ident != 1:
    bad.append("continuous-engine greedy tokens differ from the "
               "alternating baseline (want bit-identical)")
if speedup is None or speedup < 1.3:
    bad.append(f"continuous-batching speedup {speedup}x < 1.3x floor")
if bad:
    sys.exit("; ".join(bad))
print(f"serve OK: {speedup}x tokens/sec over the alternating loop "
      f"(floor 1.3x), bit-identical greedy tokens, overlap ratio "
      f"{d['overlap_ratio']}")
PY
then
  record serve 1 "$(cat .tier1_serve_detail.json)"
  rm -f .tier1_serve_detail.json
else
  record serve 0 "$(cat .tier1_serve_detail.json 2>/dev/null || echo '{}')"
  rm -f .tier1_serve_detail.json
  exit 1
fi
fi

if want bench_compare; then
echo "== bench regression gate (fresh BENCH_*.json vs baselines) =="
if python scripts/bench_compare.py; then
  BC_DETAIL="$(python - <<'PY'
import json
rows = open("artifacts/bench_delta.md").read().count("| ok |")
print(json.dumps({"metrics_ok": rows, "delta": "artifacts/bench_delta.md"}))
PY
)"
  record bench_compare 1 "$BC_DETAIL"
else
  record bench_compare 0 '{"delta": "artifacts/bench_delta.md"}'
  exit 1
fi
fi

echo "== tier-1 gate passed =="
