#!/usr/bin/env python
"""Seeded chaos sweep: every fault site × the fast-set kernels.

For each scenario the sweep arms one fault site
(:data:`repro.core.resilience.FAULT_SITES`), runs the request through
the hardened pipeline, and asserts the resilience contract:

* **scheduling sites** (``ilp.solve``, ``farkas.project``, ``fm.bounds``,
  ``cache.read``, ``cache.write``) — :func:`schedule_with_ladder` must
  return a *legal* schedule (verified differentially against the
  program-order numpy oracle, faults disarmed for the verification) and
  must be **bit-deterministic**: the same seed + the same armed faults
  walked twice produce identical schedule fingerprints and the same
  ladder rung;
* **measurement sites** (``cc.compile``, ``cc.run``, ``measure``, plus
  the crunner result-cache reads/writes) — ``measure_source`` must
  either succeed (cache faults are absorbed by quarantine-and-recompute)
  or raise a *clean typed* ``MeasurementError``, never anything else;
* **corruption** — a truncated schedule-cache pickle and a garbage
  crunner result-cache JSON are quarantined and recomputed, counted in
  ``CacheStats``;
* **deadlines** — an already-expired deadline degrades to the identity
  rung, still legal, still deterministic;
* **daemon** (``repro.launch.schedd``, real subprocesses) — every way a
  peer or the daemon process can die mid-conversation (kill -9 during a
  journalled autotune, garbage/truncated/oversized frames, a slow-loris
  client, a stale-version peer, overload shedding, a missing socket)
  ends in a typed error or a legal schedule via the client's in-process
  fallback — never a hang, a crash, or a poisoned cache pool.

Any escaped exception, illegal schedule, fingerprint mismatch between
the two runs, or armed-but-never-fired site fails the sweep.  Results
go to ``artifacts/chaos_summary.json`` (``--out`` to change); exit
status is nonzero on any failure.  Gated in ``scripts/tier1.sh`` under
a 120 s budget.
"""
import argparse
import json
import os
import pickle
import shutil
import sys
import tempfile
import time
import traceback

# isolated caches: the sweep corrupts and quarantines them on purpose
_TMP = tempfile.mkdtemp(prefix="polytops_chaos_")
os.environ["POLYTOPS_CC_CACHE"] = os.path.join(_TMP, "cc")
os.environ["POLYTOPS_SCHED_CACHE"] = os.path.join(_TMP, "sched")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core.cbackend import init_arrays  # noqa: E402
from repro.core.codegen import CodeGenerator, interpret_scop  # noqa: E402
from repro.core.config import tensor_style  # noqa: E402
from repro.core.resilience import (REGISTRY, Deadline,  # noqa: E402
                                   MeasurementError, provenance,
                                   schedule_with_ladder)
from repro.core.schedcache import (ScheduleCache,  # noqa: E402
                                   schedule_fingerprint)
from repro.core.scops_polybench import (make_gemm, make_gesummv,  # noqa: E402
                                        make_jacobi1d, make_jacobi2d,
                                        make_mvt, make_trmm)

# the PolyBench fast set at oracle-feasible sizes (mirrors the
# regression basket of benchmarks/bench_polybench.py)
FAST_KERNELS = {
    "gemm": lambda: make_gemm(13),
    "mvt": lambda: make_mvt(14),
    "jacobi1d": lambda: make_jacobi1d((5, 17)),
    "jacobi2d": lambda: make_jacobi2d((4, 11)),
    "trmm": lambda: make_trmm(11),
    "gesummv": lambda: make_gesummv(12),
}
SCALARS = {"alpha": 1.5, "beta": 0.7}

SCHED_SITES = ("ilp.solve", "farkas.project", "fm.bounds",
               "cache.read", "cache.write")
#: sites hammered in unlimited mode too (every firing fails) — the
#: scheduling-critical ones, where "forever" drives the ladder all the
#: way down; restricted to two kernels to stay inside the time budget
FOREVER_SITES = ("ilp.solve", "farkas.project", "fm.bounds")
FOREVER_KERNELS = ("gemm", "jacobi1d")

#: a minimal well-formed measurement target for the crunner sites
TINY_C = """
#include <stdio.h>
#define REPEATS 1
int main(void) {
    double acc = 0.0;
    for (int r = 0; r < REPEATS; ++r)
        for (int i = 0; i < 1000; ++i) acc += (double)i * 1e-6;
    printf("TIME_S 0.05 CHECKSUM %.17g\\n", acc);
    return 0;
}
"""


def _oracle_check(scop, sched) -> None:
    """Differential legality check: the scheduled numpy emitter must
    reproduce the program-order oracle exactly (faults must already be
    disarmed — this is harness-side verification)."""
    fn, src = CodeGenerator(sched).build()
    a1 = init_arrays(scop)
    a2 = {k: v.copy() for k, v in a1.items()}
    sc = {k: SCALARS.get(k, 1.0) for k in scop.scalars}
    interpret_scop(scop, a1, sc)
    fn(**a2, **sc, **scop.params)
    for k in a1:
        np.testing.assert_allclose(
            a1[k], a2[k], rtol=1e-7, atol=1e-9,
            err_msg=f"{scop.name} {k} diverged from program order\n{src}")


_RUN_SEQ = [0]


def _one_ladder_run(kernel: str, site, times: int, deadline_s=None):
    """Arm, schedule through the ladder, disarm, verify legality.
    Returns (fingerprint, provenance-key, fired_count).

    Every run gets a fresh *disk-backed* cache directory: the
    ``cache.read``/``cache.write`` sites only exist on the disk tier,
    and a shared directory would let run 2 take a warm path run 1 never
    saw.  ``with_tree=True`` so the FM bound pass (``fm.bounds``) is
    part of the exercised pipeline, exactly as the AKG kernel-plan path
    drives it."""
    scop = FAST_KERNELS[kernel]()
    _RUN_SEQ[0] += 1
    cache = ScheduleCache(
        cache_dir=os.path.join(_TMP, f"ladder_{_RUN_SEQ[0]}"))
    REGISTRY.reset()
    if site is not None:
        REGISTRY.arm(site, times=times)
    try:
        sched = schedule_with_ladder(
            scop, tensor_style(), cache=cache, with_tree=True,
            deadline=Deadline(deadline_s) if deadline_s is not None
            else None)
    finally:
        fired = REGISTRY.fired.get(site, 0) if site is not None else 0
        REGISTRY.reset()
    _oracle_check(scop, sched)
    prov = provenance(sched)
    # reason strings may embed wall-clock elapsed times (deadline
    # breaches) — determinism is asserted on everything else
    key = {"degraded": prov["degraded"],
           "fallback_level": prov["fallback_level"], "rung": prov["rung"],
           "n_reasons": len(prov["reasons"])}
    return schedule_fingerprint(sched), key, fired


def run_sched_scenarios(results):
    for site in SCHED_SITES:
        for kernel in FAST_KERNELS:
            modes = [("once", 1)]
            if site in FOREVER_SITES and kernel in FOREVER_KERNELS:
                modes.append(("forever", -1))
            for mode, times in modes:
                name = f"sched/{site}/{kernel}/{mode}"
                t0 = time.monotonic()
                row = {"scenario": name, "site": site, "kernel": kernel,
                       "mode": mode}
                try:
                    fp1, prov1, fired1 = _one_ladder_run(kernel, site, times)
                    fp2, prov2, fired2 = _one_ladder_run(kernel, site, times)
                    row.update(fingerprint=fp1[:16], rung=prov1["rung"],
                               fallback_level=prov1["fallback_level"],
                               fired=fired1)
                    if fired1 == 0:
                        raise AssertionError(
                            f"armed site {site} never fired — sweep bug, "
                            f"not a pass")
                    if fp1 != fp2 or prov1 != prov2 or fired1 != fired2:
                        raise AssertionError(
                            f"nondeterministic under identical faults: "
                            f"run1=({fp1[:12]}, {prov1}, fired={fired1}) "
                            f"run2=({fp2[:12]}, {prov2}, fired={fired2})")
                    row["ok"] = True
                except Exception:
                    row.update(ok=False, error=traceback.format_exc())
                row["seconds"] = round(time.monotonic() - t0, 3)
                results.append(row)


def run_deadline_scenarios(results):
    for kernel in ("gemm", "mvt"):
        name = f"deadline/expired/{kernel}"
        t0 = time.monotonic()
        row = {"scenario": name, "site": None, "kernel": kernel,
               "mode": "deadline0"}
        try:
            fp1, prov1, _ = _one_ladder_run(kernel, None, 0, deadline_s=0.0)
            fp2, prov2, _ = _one_ladder_run(kernel, None, 0, deadline_s=0.0)
            row.update(fingerprint=fp1[:16], rung=prov1["rung"],
                       fallback_level=prov1["fallback_level"])
            if not prov1["degraded"]:
                raise AssertionError(
                    f"expired deadline did not degrade: {prov1}")
            if (fp1, prov1) != (fp2, prov2):
                raise AssertionError("deadline degradation nondeterministic")
            row["ok"] = True
        except Exception:
            row.update(ok=False, error=traceback.format_exc())
        row["seconds"] = round(time.monotonic() - t0, 3)
        results.append(row)


def run_measure_scenarios(results):
    from repro.core.crunner import CACHE_DIR, measure_source

    if shutil.which("gcc") is None:
        results.append({"scenario": "measure/*", "ok": True,
                        "skipped": "no C compiler"})
        return
    expect = {"cc.compile": "compile", "cc.run": "run", "measure": "measure"}
    for site, phase in expect.items():
        name = f"measure/{site}/tiny"
        t0 = time.monotonic()
        row = {"scenario": name, "site": site, "kernel": "tiny",
               "mode": "once"}
        try:
            REGISTRY.reset()
            REGISTRY.arm(site, times=1)
            try:
                measure_source(TINY_C, tag=f"chaos_{site.replace('.', '_')}",
                               use_cache=False)
                raise AssertionError(f"armed {site} did not surface")
            except MeasurementError as e:
                if e.kind != "injected" or e.phase != phase:
                    raise AssertionError(
                        f"wrong typed error for {site}: "
                        f"kind={e.kind} phase={e.phase}") from e
                row.update(kind=e.kind, phase=e.phase,
                           fired=REGISTRY.fired.get(site, 0))
            finally:
                REGISTRY.reset()
            row["ok"] = True
        except Exception:
            row.update(ok=False, error=traceback.format_exc())
        row["seconds"] = round(time.monotonic() - t0, 3)
        results.append(row)

    # crunner cache faults are absorbed, not surfaced: quarantine (read)
    # or degrade-to-uncached (write) + recompute.  Each site gets its
    # own source text (the result-cache key is the source hash), and the
    # write fault is armed on the *first* run — the only one that
    # reaches the write path (a warm read returns before writing).
    for site in ("cache.read", "cache.write"):
        name = f"measure/{site}/tiny"
        t0 = time.monotonic()
        row = {"scenario": name, "site": site, "kernel": "tiny",
               "mode": "once"}
        src = f"// chaos {site}\n" + TINY_C
        try:
            REGISTRY.reset()
            if site == "cache.read":
                measure_source(src, tag="chaos_cache", use_cache=True)
            REGISTRY.arm(site, times=1)
            try:
                r = measure_source(src, tag="chaos_cache", use_cache=True)
            finally:
                fired = REGISTRY.fired.get(site, 0)
                REGISTRY.reset()
            if fired == 0:
                raise AssertionError(f"armed site {site} never fired")
            row.update(fired=fired, checksum=r.checksum, ok=True)
        except Exception:
            row.update(ok=False, error=traceback.format_exc())
        row["seconds"] = round(time.monotonic() - t0, 3)
        results.append(row)

    # corruption: a garbage result-cache JSON is quarantined + recomputed
    name = "corrupt/crunner-json"
    t0 = time.monotonic()
    row = {"scenario": name, "site": None, "kernel": "tiny",
           "mode": "corrupt"}
    try:
        REGISTRY.reset()
        r1 = measure_source(TINY_C, tag="chaos_corrupt", use_cache=True)
        wrote = [p for p in CACHE_DIR.glob("*.json")]
        if not wrote:
            raise AssertionError("no result-cache file to corrupt")
        for p in wrote:
            p.write_text("{truncated garbage")
        r2 = measure_source(TINY_C, tag="chaos_corrupt", use_cache=True)
        if abs(r1.checksum - r2.checksum) > 1e-12:
            raise AssertionError("recompute after corruption diverged")
        qdir = CACHE_DIR / "quarantine"
        if not (qdir.is_dir() and any(qdir.iterdir())):
            raise AssertionError("corrupt cache file was not quarantined")
        row.update(quarantined=len(list(qdir.iterdir())), ok=True)
    except Exception:
        row.update(ok=False, error=traceback.format_exc())
    row["seconds"] = round(time.monotonic() - t0, 3)
    results.append(row)


def run_corrupt_schedcache(results):
    name = "corrupt/schedcache-pickle"
    t0 = time.monotonic()
    row = {"scenario": name, "site": None, "kernel": "gemm",
           "mode": "corrupt"}
    try:
        cdir = os.path.join(_TMP, "sched_corrupt")
        scop = FAST_KERNELS["gemm"]()
        c1 = ScheduleCache(cache_dir=cdir)
        sched = schedule_with_ladder(scop, tensor_style(), cache=c1)
        fp = schedule_fingerprint(sched)
        pkls = [os.path.join(r, f) for r, _, fs in os.walk(cdir)
                for f in fs if f.endswith(".pkl") and "quarantine" not in r]
        if not pkls:
            raise AssertionError("no schedule pickle to corrupt")
        for p in pkls:
            with open(p, "wb") as f:
                f.write(pickle.dumps({"not": "a schedule"})[:7])
        c2 = ScheduleCache(cache_dir=cdir)
        again = schedule_with_ladder(FAST_KERNELS["gemm"](), tensor_style(),
                                     cache=c2)
        if schedule_fingerprint(again) != fp:
            raise AssertionError("recompute after corruption diverged")
        if c2.stats.corrupt < 1:
            raise AssertionError(
                f"corruption not counted: {c2.stats.as_dict()}")
        row.update(corrupt_counted=c2.stats.corrupt, ok=True)
    except Exception:
        row.update(ok=False, error=traceback.format_exc())
    row["seconds"] = round(time.monotonic() - t0, 3)
    results.append(row)


# ---------------------------------------------------------------------------
# schedd daemon scenarios: every way a client or the daemon process can
# die mid-conversation must end in a typed error or a legal schedule via
# the client's in-process fallback — never a hang, crash, or poisoned
# cache.  Real subprocess daemons (kill -9 has to be real); each gets a
# private socket + cache pool under the sweep's _TMP.
# ---------------------------------------------------------------------------

def _spawn_daemon(sock, pool, *extra):
    import subprocess

    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    env.pop("POLYTOPS_SCHEDD_SOCK", None)
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.schedd", "--sock", sock,
         "--cache-dir", pool, "--chaos", *extra],
        env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    from repro.core.schedclient import SchedClient

    stop = time.monotonic() + 20.0
    while time.monotonic() < stop:
        try:
            SchedClient(sock, retries=0).ping(timeout=1.0)
            return proc
        except Exception:
            if proc.poll() is not None:
                raise RuntimeError(f"daemon exited rc={proc.returncode}")
            time.sleep(0.05)
    proc.kill()
    raise RuntimeError("daemon never answered ping")


def _kill_daemon(proc):
    import subprocess

    if proc.poll() is None:
        proc.kill()
    try:
        proc.wait(timeout=5.0)
    except subprocess.TimeoutExpired:
        pass


def _daemon_scenario(results, name, fn):
    t0 = time.monotonic()
    row = {"scenario": f"daemon/{name}", "site": None, "kernel": "daemon",
           "mode": "daemon"}
    try:
        row.update(fn() or {})
        row["ok"] = True
    except Exception:
        row.update(ok=False, error=traceback.format_exc())
    row["seconds"] = round(time.monotonic() - t0, 3)
    results.append(row)


def run_daemon_scenarios(results):
    import socket as socketlib
    import threading

    from repro.core.schedclient import (MAGIC, DaemonUnavailable, Overloaded,
                                        SchedClient, SchedClientError,
                                        VersionSkew, wire_versions)

    scop_fn = FAST_KERNELS["gemm"]

    def fallback_schedule(client):
        """Schedule through the total client API, oracle-check the
        result, and return its fingerprint + the client's tallies."""
        scop = scop_fn()
        sched = client.schedule(scop)
        _oracle_check(scop, sched)
        return schedule_fingerprint(sched), client.stats.as_dict()

    # one shared hostile-input daemon: max-inflight 1 (overload is a
    # one-extra-request affair) and a 1s recv timeout (slow-loris trips
    # fast); requests in these scenarios never overlap except on purpose
    sock = os.path.join(_TMP, "schedd.sock")
    pool = os.path.join(_TMP, "schedd_pool")
    daemon = _spawn_daemon(sock, pool, "--max-inflight", "1",
                           "--conn-timeout", "1.0",
                           "--push-storm-max", "3",
                           "--push-storm-window", "60")

    def garbage_frame():
        s = socketlib.socket(socketlib.AF_UNIX, socketlib.SOCK_STREAM)
        s.settimeout(5.0)
        s.connect(sock)
        s.sendall(b"GET / HTTP/1.1\r\n\r\n" * 4)
        try:
            reply = s.recv(1 << 16)     # typed bad_frame or clean close
        except OSError:
            reply = b""
        s.close()
        if reply and b"bad_frame" not in reply:
            raise AssertionError(f"garbage got a non-typed reply: "
                                 f"{reply[:80]!r}")
        SchedClient(sock, retries=0).ping(timeout=2.0)   # daemon lives
        return {"reply_bytes": len(reply)}

    def truncated_frame():
        import struct
        s = socketlib.socket(socketlib.AF_UNIX, socketlib.SOCK_STREAM)
        s.settimeout(5.0)
        s.connect(sock)
        s.sendall(MAGIC + struct.pack(">I", 4096) + b"only-a-few-bytes")
        s.close()                        # EOF mid-frame
        SchedClient(sock, retries=0).ping(timeout=2.0)
        return {}

    def oversized_frame():
        import struct
        s = socketlib.socket(socketlib.AF_UNIX, socketlib.SOCK_STREAM)
        s.settimeout(5.0)
        s.connect(sock)
        s.sendall(MAGIC + struct.pack(">I", 0xFFFFFFFF))
        try:
            reply = s.recv(1 << 16)
        except OSError:
            reply = b""
        s.close()
        if reply and b"bad_frame" not in reply:
            raise AssertionError(f"oversized length not rejected typed: "
                                 f"{reply[:80]!r}")
        SchedClient(sock, retries=0).ping(timeout=2.0)
        return {}

    def slow_loris():
        s = socketlib.socket(socketlib.AF_UNIX, socketlib.SOCK_STREAM)
        s.settimeout(5.0)
        s.connect(sock)
        s.sendall(MAGIC[:2])             # stall mid-header
        t0 = time.monotonic()
        try:
            dropped = s.recv(1) == b""   # daemon must hang up on us
        except OSError:
            dropped = True
        held = time.monotonic() - t0
        s.close()
        if not dropped:
            raise AssertionError("daemon kept the stalled connection")
        if held > 4.0:
            raise AssertionError(f"stalled peer held {held:.1f}s "
                                 f"(conn-timeout is 1s)")
        SchedClient(sock, retries=0).ping(timeout=2.0)
        return {"held_s": round(held, 2)}

    def version_skew():
        stale = dict(wire_versions(), cache=-1, tree=-1)
        c = SchedClient(sock, retries=0, versions=stale)
        try:
            c.remote_plan("matmul", 32, 32, 32, "tensor")
            raise AssertionError("stale peer was not rejected")
        except VersionSkew:
            pass
        if c.breaker.state == "closed":
            raise AssertionError("skew did not open the breaker")
        # the total API still serves, in-process, without re-dialing
        fp, stats = fallback_schedule(c)
        if stats["fallbacks"] < 1 or stats["version_skew"] < 1:
            raise AssertionError(f"skew fallback not tallied: {stats}")
        clean = SchedClient(sock, retries=0)
        counters = clean.daemon_stats()["counters"]
        if counters["version_skew"] < 1:
            raise AssertionError(f"daemon did not count the skewed peer: "
                                 f"{counters}")
        return {"fingerprint": fp[:16], "breaker": c.breaker.state}

    def overload():
        slow_err = []

        def hold_the_flight():
            try:
                c = SchedClient(sock, retries=0, request_timeout=30.0)
                c._request({"op": "schedule", "scop": FAST_KERNELS["mvt"](),
                            "test_delay_s": 1.5}, 30.0)
            except Exception as e:       # noqa: BLE001 — asserted below
                slow_err.append(f"{type(e).__name__}: {e}")

        t = threading.Thread(target=hold_the_flight)
        t.start()
        time.sleep(0.4)                  # let it own the only flight slot
        c = SchedClient(sock, retries=0)
        try:
            c._request({"op": "schedule", "scop": scop_fn()}, 10.0)
            raise AssertionError("second keyed request was not shed "
                                 "(max-inflight is 1)")
        except Overloaded:
            pass
        # the total API degrades to in-process while the daemon is busy
        fp, stats = fallback_schedule(c)
        if stats["fallbacks"] < 1 or stats["overloaded"] < 1:
            raise AssertionError(f"overload fallback not tallied: {stats}")
        t.join(timeout=30.0)
        if slow_err:
            raise AssertionError(f"the in-flight request died: {slow_err}")
        return {"fingerprint": fp[:16]}

    def push_storm():
        """A fleet's worth of winner pushes against a daemon capped at 3
        per window: exactly 3 admitted, the rest refused-and-tallied,
        and the daemon keeps serving."""
        c = SchedClient(sock, retries=0)
        admitted = capped = 0
        for i in range(6):
            resp = c._request(
                {"op": "winner_push",
                 "key": ("schedule", f"storm-{i}", False),
                 "resp": {"ok": True, "schedule": None,
                          "meta": {"degraded": False}},
                 "compute_s": 1.0}, 10.0)
            admitted += 1 if resp.get("admitted") else 0
            capped += 1 if resp.get("capped") else 0
        if admitted != 3 or capped != 3:
            raise AssertionError(f"storm cap broken: admitted={admitted} "
                                 f"capped={capped} (cap is 3)")
        st = c.daemon_stats()
        if st["counters"]["peer_pushes_capped"] < 3:
            raise AssertionError(f"capped pushes not counted: "
                                 f"{st['counters']}")
        if st["frames"]["stats"]["push_capped"] < 3:
            raise AssertionError(f"push_capped missing from CacheStats: "
                                 f"{st['frames']['stats']}")
        SchedClient(sock, retries=0).ping(timeout=2.0)   # daemon lives
        return {"admitted": admitted, "capped": capped}

    try:
        _daemon_scenario(results, "garbage-frame", garbage_frame)
        _daemon_scenario(results, "truncated-frame", truncated_frame)
        _daemon_scenario(results, "oversized-frame", oversized_frame)
        _daemon_scenario(results, "slow-loris", slow_loris)
        _daemon_scenario(results, "stale-version-peer", version_skew)
        _daemon_scenario(results, "overload-shed", overload)
        _daemon_scenario(results, "push-storm", push_storm)
    finally:
        try:
            SchedClient(sock, retries=0).shutdown(timeout=2.0)
        except Exception:
            pass
        _kill_daemon(daemon)

    def socket_enoent():
        c = SchedClient(os.path.join(_TMP, "no-such.sock"), retries=0,
                        connect_timeout=0.2)
        try:
            c.remote_plan("matmul", 32, 32, 32, "tensor")
            raise AssertionError("missing socket did not raise typed")
        except DaemonUnavailable:
            pass
        fp1, _ = fallback_schedule(c)
        fp2, stats = fallback_schedule(c)
        if fp1 != fp2:
            raise AssertionError("fallback schedule nondeterministic")
        if stats["fallbacks"] < 2:
            raise AssertionError(f"fallbacks not tallied: {stats}")
        return {"fingerprint": fp1[:16]}

    _daemon_scenario(results, "socket-enoent", socket_enoent)

    def kill9_mid_request():
        k_sock = os.path.join(_TMP, "schedd_kill.sock")
        k_pool = os.path.join(_TMP, "schedd_kill_pool")
        proc = _spawn_daemon(k_sock, k_pool)
        victim_err = []

        def victim():
            try:
                c = SchedClient(k_sock, retries=0, request_timeout=30.0)
                c._request({"op": "autotune", "scop": scop_fn(),
                            "kwargs": {"measure": False},
                            "test_delay_s": 5.0}, 30.0)
                victim_err.append("request SUCCEEDED across a kill -9")
            except SchedClientError:
                pass                     # typed: exactly the contract
            except Exception as e:       # noqa: BLE001 — asserted below
                victim_err.append(f"untyped: {type(e).__name__}: {e}")

        t = threading.Thread(target=victim)
        t.start()
        time.sleep(1.0)                  # inside the journalled hold
        proc.kill()                      # SIGKILL: no cleanup, no goodbye
        proc.wait(timeout=5.0)
        t.join(timeout=30.0)
        if t.is_alive():
            raise AssertionError("client hung across the daemon's death")
        if victim_err:
            raise AssertionError(victim_err[0])

        # the orphaned socket file now points nowhere: the total API
        # must fall back in-process and still produce a legal schedule
        c = SchedClient(k_sock, retries=0, connect_timeout=0.5)
        fp, stats = fallback_schedule(c)
        if stats["fallbacks"] < 1:
            raise AssertionError(f"post-kill fallback not tallied: {stats}")

        # restart on the same pool: nothing is torn, and the journal
        # names the autotune the kill orphaned
        proc2 = _spawn_daemon(k_sock, k_pool)
        try:
            clean = SchedClient(k_sock, retries=0)
            st = clean.daemon_stats()
            if st["journal_recovered"] < 1:
                raise AssertionError(
                    f"journal did not witness the killed autotune: {st}")
            sched = clean.schedule(scop_fn())
            _oracle_check(scop_fn(), sched)
            if clean.stats.fallbacks:
                raise AssertionError("restarted daemon did not serve")
            from repro.core.schedcache import (ScheduleCache,
                                               load_measurements)
            load_measurements(ScheduleCache(cache_dir=k_pool))
        finally:
            try:
                SchedClient(k_sock, retries=0).shutdown(timeout=2.0)
            except Exception:
                pass
            _kill_daemon(proc2)
        return {"fingerprint": fp[:16],
                "journal_recovered": st["journal_recovered"]}

    _daemon_scenario(results, "kill9-mid-request", kill9_mid_request)

    def kill9_pool_worker():
        """A poison request SIGKILLs its pool worker twice (the daemon
        retries once on a fresh fork).  Contract: typed error to the
        client, the daemon survives and keeps serving, the crash is
        journalled as *witnessed* — a restart on the same pool must NOT
        count it as an unwitnessed kill -9 orphan."""
        w_sock = os.path.join(_TMP, "schedd_worker.sock")
        w_pool = os.path.join(_TMP, "schedd_worker_pool")
        proc = _spawn_daemon(w_sock, w_pool, "--workers", "2")
        try:
            c = SchedClient(w_sock, retries=0, request_timeout=60.0)
            try:
                c._request({"op": "autotune", "scop": scop_fn(),
                            "kwargs": {"measure": False},
                            "test_kill_worker": True}, 60.0)
                raise AssertionError("poison request SUCCEEDED across "
                                     "two worker kills")
            except SchedClientError as e:
                kind = getattr(e, "kind", None)
                if kind != "worker_crashed":
                    raise AssertionError(
                        f"poison surfaced as {type(e).__name__} "
                        f"(kind={kind!r}), not worker_crashed")
            clean = SchedClient(w_sock, retries=0)
            st = clean.daemon_stats()
            if st["counters"]["worker_crashes"] != 2:
                raise AssertionError(
                    f"poison should burn exactly 2 workers: "
                    f"{st['counters']}")
            # the pool respawned and the daemon still schedules
            sched = clean.schedule(scop_fn())
            _oracle_check(scop_fn(), sched)
            if clean.stats.fallbacks:
                raise AssertionError("daemon stopped serving after the "
                                     "worker kills")
        finally:
            try:
                SchedClient(w_sock, retries=0).shutdown(timeout=2.0)
            except Exception:
                pass
            _kill_daemon(proc)

        # restart on the same pool: the witnessed crash completed its
        # journal begin, so recovery finds no orphans
        proc2 = _spawn_daemon(w_sock, w_pool)
        try:
            st2 = SchedClient(w_sock, retries=0).daemon_stats()
            if st2["journal_recovered"] != 0:
                raise AssertionError(
                    f"witnessed worker crash was re-counted as an "
                    f"orphan: {st2['journal_recovered_keys']}")
        finally:
            try:
                SchedClient(w_sock, retries=0).shutdown(timeout=2.0)
            except Exception:
                pass
            _kill_daemon(proc2)
        return {"worker_crashes": 2}

    _daemon_scenario(results, "kill9-pool-worker", kill9_pool_worker)


# ---------------------------------------------------------------------------
# TCP auth scenarios: the shared-key handshake is a hard gate.  A wrong
# key gets a typed ``auth_failed`` and never reaches the pickle codec;
# a tampered post-handshake frame is rejected on the MAC before decode.
# Either way the daemon keeps serving correctly-keyed peers.
# ---------------------------------------------------------------------------

def run_tcp_auth_scenarios(results):
    import socket as socketlib
    import subprocess

    from repro.core import wire
    from repro.core.schedclient import AuthFailed, SchedClient

    key = b"chaos-sweep-shared-key"
    sock = os.path.join(_TMP, "schedd_tcp.sock")
    pool = os.path.join(_TMP, "schedd_tcp_pool")
    port_file = os.path.join(_TMP, "schedd_tcp.port")

    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    env.pop("POLYTOPS_SCHEDD_SOCK", None)
    env[wire.KEY_ENV] = key.decode()
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.schedd", "--sock", sock,
         "--cache-dir", pool, "--chaos", "--listen", "127.0.0.1:0",
         "--port-file", port_file],
        env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    stop = time.monotonic() + 20.0
    addr = None
    while time.monotonic() < stop:
        if os.path.exists(port_file):
            addr = "127.0.0.1:" + open(port_file).read().strip()
            try:
                SchedClient(addr, retries=0, key=key).ping(timeout=1.0)
                break
            except Exception:
                pass
        if proc.poll() is not None:
            raise RuntimeError(f"tcp daemon exited rc={proc.returncode}")
        time.sleep(0.05)
    else:
        proc.kill()
        raise RuntimeError("tcp daemon never answered ping")

    def wrong_key():
        bad = SchedClient(addr, retries=0, key=b"not-the-key")
        try:
            bad.ping(timeout=2.0)
            raise AssertionError("wrong key was accepted")
        except AuthFailed:
            pass
        finally:
            bad.close()
        # the daemon survives, counts it, and keeps serving good peers
        good = SchedClient(addr, retries=0, key=key)
        good.ping(timeout=2.0)
        counters = good.daemon_stats()["counters"]
        good.close()
        if counters["auth_failed"] < 1:
            raise AssertionError(
                f"rejected handshake not counted: {counters}")
        return {"auth_failed": counters["auth_failed"]}

    def tampered_mac():
        host, port = addr.rsplit(":", 1)
        s = socketlib.socket(socketlib.AF_INET, socketlib.SOCK_STREAM)
        s.settimeout(5.0)
        s.connect((host, int(port)))
        hello = {"op": "hello", **wire.wire_versions()}
        _, session = wire.client_handshake(s, hello, key=key)
        if session is None:
            raise AssertionError("TCP handshake produced no session")
        frame = wire.encode_frame({"op": "ping"}, session=session)
        frame = frame[:-1] + bytes([frame[-1] ^ 0xFF])   # flip a MAC bit
        s.sendall(frame)
        try:
            reply = s.recv(1 << 16)      # typed auth_failed or clean close
        except OSError:
            reply = b""
        s.close()
        if reply and b"auth_failed" not in reply:
            raise AssertionError(
                f"tampered frame got a non-typed reply: {reply[:80]!r}")
        good = SchedClient(addr, retries=0, key=key)
        good.ping(timeout=2.0)           # daemon lives
        counters = good.daemon_stats()["counters"]
        good.close()
        if counters["auth_failed"] < 2:  # wrong_key ran first
            raise AssertionError(
                f"tampered frame not counted: {counters}")
        return {"reply_bytes": len(reply)}

    try:
        _daemon_scenario(results, "tcp-wrong-key", wrong_key)
        _daemon_scenario(results, "tcp-tampered-mac", tampered_mac)
    finally:
        try:
            SchedClient(sock, retries=0).shutdown(timeout=2.0)
        except Exception:
            pass
        _kill_daemon(proc)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="artifacts/chaos_summary.json")
    args = ap.parse_args(argv)
    t0 = time.monotonic()
    results = []
    run_sched_scenarios(results)
    run_deadline_scenarios(results)
    run_measure_scenarios(results)
    run_corrupt_schedcache(results)
    run_daemon_scenarios(results)
    run_tcp_auth_scenarios(results)
    failures = [r for r in results if not r.get("ok")]
    summary = {
        "ok": not failures,
        "n_scenarios": len(results),
        "n_failures": len(failures),
        "seconds": round(time.monotonic() - t0, 2),
        "scenarios": results,
    }
    outdir = os.path.dirname(args.out)
    if outdir:
        os.makedirs(outdir, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(summary, f, indent=2, sort_keys=True)
    for r in results:
        mark = "ok " if r.get("ok") else "FAIL"
        extra = (f" rung={r['rung']}" if "rung" in r else "") + \
                (f" fired={r['fired']}" if "fired" in r else "")
        print(f"{mark} {r['scenario']}{extra} ({r.get('seconds', 0)}s)")
    print(f"chaos sweep: {len(results) - len(failures)}/{len(results)} "
          f"scenarios ok in {summary['seconds']}s -> {args.out}")
    if failures:
        for r in failures:
            print(f"-- {r['scenario']} --\n{r.get('error', '')}",
                  file=sys.stderr)
        return 1
    shutil.rmtree(_TMP, ignore_errors=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
