"""Batched serving: prefill a batch of prompts, then decode with the
serve step (KV/SSM caches), greedy sampling.

    PYTHONPATH=src python examples/serve_batched.py [--arch falcon_mamba_7b]
"""
import argparse
import sys
import time
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.model import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch).smoke()
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    b, pl_, max_len = args.batch, args.prompt_len, args.prompt_len + args.gen

    prompts = jax.random.randint(key, (b, pl_), 2, cfg.vocab)
    t0 = time.time()
    prefill = jax.jit(lambda p, t: T.prefill(p, cfg, t))
    logits, pre_cache = prefill(params, prompts)
    print(f"prefill {b}×{pl_}: {time.time()-t0:.2f}s "
          f"(logits {logits.shape})")

    # widen the prefill cache to max_len
    cache = T.init_cache(cfg, b, max_len)

    def widen(dst, src):
        if dst.ndim == src.ndim and dst.shape[-2:] == src.shape[-2:] \
                and src.shape[-3] <= dst.shape[-3]:
            return jax.lax.dynamic_update_slice(
                dst, src.astype(dst.dtype), (0,) * dst.ndim)
        return src.astype(dst.dtype)

    cache = jax.tree.map(widen, cache, pre_cache)

    step = jax.jit(lambda p, tok, c, n: T.decode_step(p, cfg, tok, c, n))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits_i, cache = step(params, tok, cache, jnp.int32(pl_ + i))
        tok = jnp.argmax(logits_i, -1).astype(jnp.int32)[:, None]
        out_tokens.append(tok)
    gen = jnp.concatenate(out_tokens, axis=1)
    dt = time.time() - t0
    print(f"decoded {args.gen-1} steps × {b} seqs in {dt:.2f}s "
          f"({(args.gen-1)*b/max(dt,1e-9):.1f} tok/s on CPU smoke config)")
    print("sample tokens:", gen[0][:12].tolist())


if __name__ == "__main__":
    main()
