"""Kernel-specific configuration via the JSON interface (paper Listing 2)
→ schedule → generated C, end to end.

    PYTHONPATH=src python examples/schedule_and_generate.py
"""
import sys
sys.path.insert(0, "src")

from repro.core.cbackend import CCodeGenerator
from repro.core.config import SchedulerConfig
from repro.core.crunner import compile_and_run
from repro.core.postproc import tile_schedule
from repro.core.scheduler import schedule_scop
from repro.core.scops_npu import make_trsml

CONFIG_JSON = {
    "scheduling_strategy": {
        "name": "trsml-kernel-specific",
        "ILP_construction": [
            {"scheduling_dimension": "default",
             "cost_functions": ["contiguity", "proximity"],
             "constraints": ["no-skewing"]},
        ],
        "directives": [
            {"type": "parallel", "stmts": [0, 1], "iterator": 2},
            {"type": "vectorize", "stmts": [0], "iterator": 3},
            {"type": "vectorize", "stmts": [1], "iterator": 3},
        ],
    }
}


def main():
    scop = make_trsml(64, 64, 512)
    cfg = SchedulerConfig.from_json(CONFIG_JSON)
    sched = schedule_scop(scop, cfg)
    print("schedule:")
    print(sched.pretty())
    print("\ndropped directives:", sched.dropped_directives)
    src = CCodeGenerator(sched, scalars={}).generate()
    kernel = src[src.index("static void kernel"):src.index("#define REPEATS")]
    print("\ngenerated C kernel:\n")
    print(kernel)
    r = compile_and_run(src, tag="trsml_example")
    print(f"measured: {r.seconds*1e6:.1f} us/call checksum={r.checksum:.6e}")

    # tiled variants of the same schedule: fixed sizes vs cache model
    scan = tile_schedule(sched, 32)
    src_t = CCodeGenerator(sched, scan=scan, scalars={}).generate()
    rt = compile_and_run(src_t, tag="trsml_example_tiled")
    print(f"tiled 32: {rt.seconds*1e6:.1f} us/call checksum={rt.checksum:.6e}")
    scan_m = tile_schedule(sched, "l2")   # cache-model sizes (see EXPERIMENTS.md)
    src_m = CCodeGenerator(sched, scan=scan_m, scalars={}).generate()
    rm = compile_and_run(src_m, tag="trsml_example_l2")
    print(f"tiled l2: {rm.seconds*1e6:.1f} us/call checksum={rm.checksum:.6e}")

    # or let the autotuner pick the whole configuration (strategy × tile
    # × wavefront), persisted in the schedule cache by SCoP structure
    from repro.core.autotune import autotune
    tuned = autotune(scop)
    print(f"autotuned: {tuned.config.label} "
          f"({(tuned.seconds or 0)*1e6:.1f} us/call, source={tuned.source})")


if __name__ == "__main__":
    main()
