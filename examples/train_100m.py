"""End-to-end training driver: ~100M-parameter dense model, synthetic
data, checkpoint/restart, straggler monitoring.

    PYTHONPATH=src python examples/train_100m.py            # full (~100M)
    PYTHONPATH=src python examples/train_100m.py --smoke    # CI-sized

The full run is sized for a real accelerator; --smoke runs in ~a minute
on CPU and exercises the identical code path (including a simulated
preemption + restore at step 12).
"""
import argparse
import sys
sys.path.insert(0, "src")

from repro.configs.registry import get_arch
from repro.optim.adamw import AdamWConfig
from repro.train import fault as FAULT
from repro.train.loop import Trainer, TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt", default="/tmp/repro_train_100m")
    ap.add_argument("--simulate-preemption", action="store_true")
    args = ap.parse_args()

    base = get_arch("qwen3_0_6b")
    if args.smoke:
        arch = base.smoke()
        cfg = TrainConfig(arch=arch, total_steps=args.steps or 40,
                          global_batch=4, seq_len=64, ckpt_dir=args.ckpt,
                          ckpt_every=10, log_every=5,
                          opt=AdamWConfig(lr=1e-3, warmup_steps=10,
                                          total_steps=40))
    else:
        # ~100M: 12 layers × d512 × ff2048 + 152k vocab embeddings
        arch = base.scaled(n_layers=12, d_model=512, n_heads=8, n_kv_heads=4,
                           d_ff=2048, head_dim=64)
        cfg = TrainConfig(arch=arch, total_steps=args.steps or 300,
                          global_batch=8, seq_len=512, ckpt_dir=args.ckpt,
                          ckpt_every=50, log_every=10,
                          opt=AdamWConfig(lr=6e-4, warmup_steps=30,
                                          total_steps=300))

    trainer = Trainer(cfg)
    if args.simulate_preemption:
        orig = trainer.run_step

        def flaky(step):
            if step == 12 and not getattr(flaky, "fired", False):
                flaky.fired = True
                raise FAULT.Preemption("simulated node loss")
            return orig(step)

        trainer.run_step = flaky
    out = trainer.fit()
    losses = [h["loss"] for h in trainer.history]
    print(f"\nsteps={out['final_step']} restarts={out['restarts']} "
          f"stragglers={len(out['stragglers'])}")
    print(f"loss: first={losses[0]:.4f} last={losses[-1]:.4f} "
          f"(decreased: {losses[-1] < losses[0]})")
    trainer.close()


if __name__ == "__main__":
    main()
