"""PolyTOPS quickstart: schedule a kernel four ways, generate code, run it.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

import numpy as np

from repro.core import config as CFG
from repro.core.codegen import CodeGenerator, interpret_scop
from repro.core.scheduler import schedule_scop
from repro.core.scop import Scop


def build_gemm(n=64):
    k = Scop("gemm", params={"N": n})
    with k.loop("i", 0, "N"):
        with k.loop("j", 0, "N"):
            k.stmt("C[i,j] = C[i,j] * beta")
            with k.loop("kk", 0, "N"):
                k.stmt("C[i,j] = C[i,j] + alpha * A[i,kk] * B[kk,j]")
    return k


def main():
    scop = build_gemm()
    print(f"SCoP: {scop}\n")
    for make in (CFG.pluto_style, CFG.tensor_style, CFG.isl_style,
                 CFG.feautrier_style):
        cfg = make()
        sched = schedule_scop(scop, cfg)
        print(f"=== {cfg.name} ===")
        print(sched.pretty())
        fn, src = CodeGenerator(sched).build()
        rng = np.random.default_rng(0)
        n = scop.params["N"]
        arrays = {"A": rng.standard_normal((n, n)),
                  "B": rng.standard_normal((n, n)),
                  "C": rng.standard_normal((n, n))}
        ref = {k: v.copy() for k, v in arrays.items()}
        interpret_scop(scop, ref, {"alpha": 1.5, "beta": 0.5})
        fn(**arrays, alpha=1.5, beta=0.5, N=n)
        ok = np.allclose(arrays["C"], ref["C"])
        print(f"matches original semantics: {ok}\n")
    print("The tensor-style (i,k,j) interchange is the paper's Listing-1 "
          "mechanism: contiguity puts the stride-1 iterator innermost.")


if __name__ == "__main__":
    main()
